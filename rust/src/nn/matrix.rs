//! Row-major dense matmul kernels for the native trainer's three junction
//! operations (FF / BP / UP in matrix form). Loop orders are chosen for
//! unit-stride inner loops (see DESIGN.md §Perf), and every kernel is
//! batch-parallel: the output rows (FF/BP) or the batch reduction (UP)
//! are chunked over the [`crate::util::parallel`] thread pool when the
//! problem is big enough to amortize the fork-join.

use crate::util::parallel;

/// out[m,n] = a[m,k] @ b[n,k]^T  (FF: h = a @ W^T with W = [n_right, n_left])
pub fn matmul_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), n * k);
    assert_eq!(out.len(), m * n);
    parallel::par_rows(out, n, k * n, |row0, chunk| {
        for (li, or) in chunk.chunks_mut(n).enumerate() {
            let i = row0 + li;
            let ar = &a[i * k..(i + 1) * k];
            for (j, o) in or.iter_mut().enumerate() {
                let br = &b[j * k..(j + 1) * k];
                let mut acc = 0f32;
                // unit stride over both operands; autovectorizes well
                for t in 0..k {
                    acc += ar[t] * br[t];
                }
                *o = acc;
            }
        }
    });
}

/// out[m,n] = a[m,k] @ b[k,n]  (BP: da = delta @ W)
pub fn matmul_nn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(out.len(), m * n);
    parallel::par_rows(out, n, k * n, |row0, chunk| {
        chunk.fill(0.0);
        for (li, or) in chunk.chunks_mut(n).enumerate() {
            let i = row0 + li;
            for t in 0..k {
                let av = a[i * k + t];
                if av == 0.0 {
                    continue;
                }
                let br = &b[t * n..(t + 1) * n];
                for (o, &bv) in or.iter_mut().zip(br) {
                    *o += av * bv;
                }
            }
        }
    });
}

/// out[m,n] += scale * a[k,m]^T @ b[k,n]  (UP: dW = delta^T @ a)
pub fn matmul_tn_acc(
    a: &[f32],
    b: &[f32],
    k: usize,
    m: usize,
    n: usize,
    scale: f32,
    out: &mut [f32],
) {
    assert_eq!(a.len(), k * m);
    assert_eq!(b.len(), k * n);
    assert_eq!(out.len(), m * n);
    parallel::par_batch_reduce(k, m * n, out, |range, acc| {
        for t in range {
            let ar = &a[t * m..(t + 1) * m];
            let br = &b[t * n..(t + 1) * n];
            for (i, &av) in ar.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let or = &mut acc[i * n..(i + 1) * n];
                let s = scale * av;
                for (o, &bv) in or.iter_mut().zip(br) {
                    *o += s * bv;
                }
            }
        }
    });
}

/// out[i, :] += v (bias broadcast)
pub fn add_bias(out: &mut [f32], v: &[f32], m: usize, n: usize) {
    assert_eq!(out.len(), m * n);
    assert_eq!(v.len(), n);
    for i in 0..m {
        for (o, &b) in out[i * n..(i + 1) * n].iter_mut().zip(v) {
            *o += b;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, ta: bool, tb: bool) -> Vec<f32> {
        let mut out = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0f32;
                for t in 0..k {
                    let av = if ta { a[t * m + i] } else { a[i * k + t] };
                    let bv = if tb { b[j * k + t] } else { b[t * n + j] };
                    acc += av * bv;
                }
                out[i * n + j] = acc;
            }
        }
        out
    }

    fn randvec(n: usize, seed: u64) -> Vec<f32> {
        let mut r = crate::util::rng::Rng::new(seed);
        (0..n).map(|_| r.normal()).collect()
    }

    #[test]
    fn nt_matches_naive() {
        let (m, k, n) = (5, 7, 3);
        let a = randvec(m * k, 0);
        let b = randvec(n * k, 1);
        let mut out = vec![0f32; m * n];
        matmul_nt(&a, &b, m, k, n, &mut out);
        let want = naive(&a, &b, m, k, n, false, true);
        for (g, w) in out.iter().zip(&want) {
            assert!((g - w).abs() < 1e-4);
        }
    }

    #[test]
    fn nn_matches_naive() {
        let (m, k, n) = (4, 6, 5);
        let a = randvec(m * k, 2);
        let b = randvec(k * n, 3);
        let mut out = vec![0f32; m * n];
        matmul_nn(&a, &b, m, k, n, &mut out);
        let want = naive(&a, &b, m, k, n, false, false);
        for (g, w) in out.iter().zip(&want) {
            assert!((g - w).abs() < 1e-4);
        }
    }

    #[test]
    fn tn_acc_matches_naive_with_scale() {
        let (k, m, n) = (6, 4, 3);
        let a = randvec(k * m, 4);
        let b = randvec(k * n, 5);
        let mut out = vec![1f32; m * n]; // accumulate onto ones
        matmul_tn_acc(&a, &b, k, m, n, 0.5, &mut out);
        let want = naive(&a, &b, m, k, n, true, false);
        for (g, w) in out.iter().zip(&want) {
            assert!((g - (1.0 + 0.5 * w)).abs() < 1e-4);
        }
    }

    #[test]
    fn bias_broadcast() {
        let mut out = vec![0f32; 6];
        add_bias(&mut out, &[1.0, 2.0, 3.0], 2, 3);
        assert_eq!(out, vec![1.0, 2.0, 3.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn kernels_match_under_forced_parallelism() {
        let _guard = parallel::override_guard();
        // big enough that par_rows / par_batch_reduce actually fork
        let (m, k, n) = (96usize, 64, 48);
        let a = randvec(m * k, 10);
        let bt = randvec(n * k, 11);
        let bn = randvec(k * n, 12);
        let run = |threads: usize| {
            parallel::set_threads(threads);
            let mut nt = vec![0f32; m * n];
            matmul_nt(&a, &bt, m, k, n, &mut nt);
            let mut nn = vec![0f32; m * n];
            matmul_nn(&a, &bn, m, k, n, &mut nn);
            // tn_acc reduces k items into an [m, n] output; a here is read
            // as [k, m] (element count matches, layout is irrelevant for
            // the 1-vs-N-thread comparison). m*n*k is big enough that the
            // batch reduction actually forks.
            let mut tn = vec![0f32; m * n];
            matmul_tn_acc(&a[..k * m], &bn, k, m, n, 0.5, &mut tn);
            parallel::set_threads(0);
            (nt, nn, tn)
        };
        let (nt1, nn1, tn1) = run(1);
        let (nt4, nn4, tn4) = run(4);
        for (x, y) in nt1.iter().zip(&nt4) {
            assert_eq!(x, y, "nt rows are chunk-independent");
        }
        for (x, y) in nn1.iter().zip(&nn4) {
            assert_eq!(x, y, "nn rows are chunk-independent");
        }
        for (x, y) in tn1.iter().zip(&tn4) {
            // reduction merge order differs -> tolerance compare
            assert!((x - y).abs() < 1e-3 * (1.0 + x.abs()), "{x} vs {y}");
        }
    }
}
