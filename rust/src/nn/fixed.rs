//! Fixed-point (Qm.n) execution: the arithmetic half of the hardware
//! claim.
//!
//! The paper's FPGA architecture does not compute in f32. The companion
//! implementation ("A Highly Parallel FPGA Implementation of Sparse
//! Neural Network Training", arXiv:1806.01087) runs the whole FF/BP/UP
//! pipeline in narrow signed fixed-point with saturating arithmetic and
//! an interpolated activation table, and "Sparsely-Connected Neural
//! Networks" (arXiv:1611.01427) shows quantized sparse MLPs keep their
//! accuracy at a fraction of the storage. This module is that numeric
//! universe for the reproduction:
//!
//! - [`QFormat`] — a configurable Qm.n signed fixed-point format (sign +
//!   `m` integer bits + `n` fraction bits in an `i32` word) with
//!   round-to-nearest [`QFormat::quantize`] / [`QFormat::dequantize`]
//!   and *saturating* [`QFormat::sat_add`] / [`QFormat::sat_mul`] (the
//!   hardware clamps, it never wraps),
//! - [`SigmoidLut`] — the companion hardware's activation evaluator: a
//!   sigmoid lookup table with linear interpolation between nodes and a
//!   documented worst-case error bound ([`SigmoidLut::max_error`]).
//!   The paper's MLP configs in this repo are ReLU networks, so the
//!   execution surfaces use [`relu_raw`]; the LUT is the validated
//!   building block for sigmoid-activated configs (tests pin its error
//!   bound and monotonicity), not part of the ReLU forward paths,
//! - [`FixedSparseLayer`] / [`FixedSparseNet`] — fixed-point twins of the
//!   compacted-edge [`crate::nn::sparse`] kernels (FF / BP / UP), with
//!   wide (`i64`) MAC accumulators and a single rounding shift per
//!   output, the way DSP-block MAC chains behave,
//! - [`forward_error_bound`] — the derivable |quantized − f32| forward
//!   error bound the differential tests enforce (derivation in
//!   `ARCHITECTURE.md` §Fixed-point arithmetic).
//!
//! The f32 kernels are untouched: the quantized path is a parallel
//! universe selected per call (runtime `forward_quantized` program,
//! `serve --quant`, `train --quant-eval`), never a silent replacement.
//!
//! Bit-exactness contract: [`FixedSparseLayer::forward`] and the
//! cycle-accurate [`crate::hw::junction::JunctionUnit::feedforward_quantized`]
//! produce *identical raw words* for the same junction — `i64`
//! accumulation is exact, so edge order cannot change the sum. The
//! differential tests in `tests/prop_fixed.rs` pin that contract.

// numerics boundary: every narrowing cast in this module is a deliberate
// range-checked conversion (post-clamp, post-round, or validated-format
// arithmetic), so each site carries a targeted allow with its argument —
// a new unannotated cast is a bug until proven otherwise
#![deny(clippy::cast_possible_truncation)]
#![deny(clippy::lossy_float_literal)]

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::nn::actsparse::{ActMode, ActSpec, ActStats, ActivationMask};
use crate::nn::sparse::{SparseLayer, SparseNet};
use crate::util::parallel;

/// A signed Qm.n fixed-point format: one sign bit, `int_bits` integer
/// bits, `frac_bits` fraction bits, stored in an `i32` raw word scaled by
/// `2^frac_bits`. Representable range is `[-2^m, 2^m - 2^-n]` with a
/// resolution (ULP) of `2^-n`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QFormat {
    /// Integer bits `m` (magnitude range `±2^m`).
    pub int_bits: u32,
    /// Fraction bits `n` (resolution `2^-n`).
    pub frac_bits: u32,
}

impl Default for QFormat {
    /// Q5.10: range ±32, resolution ~0.001 — enough integer headroom for
    /// every built-in config's pre-activations at normalized inputs, with
    /// a forward error bound well under the class-decision scale.
    fn default() -> Self {
        QFormat {
            int_bits: 5,
            frac_bits: 10,
        }
    }
}

impl std::fmt::Display for QFormat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Q{}.{}", self.int_bits, self.frac_bits)
    }
}

/// Round-half-up arithmetic right shift (the hardware's MAC output
/// rounding): `v / 2^n` rounded to the nearest integer, ties toward
/// +infinity. Exact in `i64` for every product of two in-range raw words.
///
/// `pub(crate)` so the static verifier's `i128` twin
/// ([`crate::analysis::range::shift_round_wide`]) can be pinned to this
/// exact rounding rule by a unit test below — the range analysis is only
/// sound if both round identically on the shared `i64` domain.
#[inline]
pub(crate) fn shift_round(v: i64, n: u32) -> i64 {
    if n == 0 {
        v
    } else {
        (v + (1i64 << (n - 1))) >> n
    }
}

impl QFormat {
    /// A validated Qm.n format; panics unless `1 <= m + n <= 31` (the
    /// word must fit an `i32` with its sign bit).
    pub fn new(int_bits: u32, frac_bits: u32) -> QFormat {
        QFormat::new_checked(int_bits, frac_bits)
            .unwrap_or_else(|| panic!("invalid fixed-point format Q{int_bits}.{frac_bits}"))
    }

    /// Like [`QFormat::new`] but `None` instead of panicking.
    pub fn new_checked(int_bits: u32, frac_bits: u32) -> Option<QFormat> {
        let bits = int_bits + frac_bits;
        if (1..=31).contains(&bits) {
            Some(QFormat {
                int_bits,
                frac_bits,
            })
        } else {
            None
        }
    }

    /// Parse `"Qm.n"` (e.g. `"Q5.10"`, case-insensitive prefix).
    pub fn parse(s: &str) -> Option<QFormat> {
        let rest = s.trim().strip_prefix(['Q', 'q'])?;
        let (m, n) = rest.split_once('.')?;
        QFormat::new_checked(m.parse().ok()?, n.parse().ok()?)
    }

    /// Total word width in bits (sign + m + n).
    pub fn word_bits(&self) -> u32 {
        1 + self.int_bits + self.frac_bits
    }

    /// Scaling factor `2^n` between real values and raw words.
    pub fn scale(&self) -> f64 {
        (1u64 << self.frac_bits) as f64
    }

    /// One unit in the last place: `2^-n`, the format's resolution.
    // 2^-n is a power of two, exactly representable in f32 for n <= 31
    #[allow(clippy::cast_possible_truncation)]
    pub fn ulp(&self) -> f32 {
        (1.0 / self.scale()) as f32
    }

    /// Largest raw word: `2^(m+n) - 1`.
    // m + n <= 31 (validated in new_checked), so the word fits an i32
    #[allow(clippy::cast_possible_truncation)]
    pub fn max_raw(&self) -> i32 {
        ((1i64 << (self.int_bits + self.frac_bits)) - 1) as i32
    }

    /// Smallest raw word: `-2^(m+n)`.
    // m + n <= 31 (validated in new_checked), so the word fits an i32
    #[allow(clippy::cast_possible_truncation)]
    pub fn min_raw(&self) -> i32 {
        (-(1i64 << (self.int_bits + self.frac_bits))) as i32
    }

    /// Largest representable real value (`2^m - 2^-n`).
    pub fn max_value(&self) -> f32 {
        self.dequantize(self.max_raw())
    }

    /// Real → raw: round to nearest, saturate at the range ends. NaN maps
    /// to zero; ±infinity saturates. Never panics.
    pub fn quantize(&self, x: f32) -> i32 {
        let mut clipped = 0usize;
        self.quantize_counted(x, &mut clipped)
    }

    /// Like [`QFormat::quantize`], counting range clips into `clipped` —
    /// a clipped value violates the |Δ| ≤ ulp/2 premise of the forward
    /// error bound, so every quantization surface that feeds the bound
    /// (parameter ingest, request inputs) counts clips instead of hiding
    /// them. Values that land exactly on the range ends without exceeding
    /// them are not clips.
    // the final `v as i32` runs only after the range comparisons above it
    // proved v lies inside [min_raw, max_raw]
    #[allow(clippy::cast_possible_truncation)]
    pub fn quantize_counted(&self, x: f32, clipped: &mut usize) -> i32 {
        if x.is_nan() {
            *clipped += 1;
            return 0;
        }
        let v = (x as f64 * self.scale()).round();
        if v > self.max_raw() as f64 {
            *clipped += 1;
            self.max_raw()
        } else if v < self.min_raw() as f64 {
            *clipped += 1;
            self.min_raw()
        } else {
            v as i32
        }
    }

    /// Raw → real (exact: every raw word is exactly representable in f32
    /// for word widths up to 25 bits, and within 1 ULP beyond).
    // the f64 quotient is finite and within f32 range for every i32 raw
    // word, so the narrowing is a rounding, never an overflow
    #[allow(clippy::cast_possible_truncation)]
    pub fn dequantize(&self, raw: i32) -> f32 {
        (raw as f64 / self.scale()) as f32
    }

    /// Quantize a slice.
    pub fn quantize_slice(&self, xs: &[f32]) -> Vec<i32> {
        xs.iter().map(|&x| self.quantize(x)).collect()
    }

    /// Quantize a slice, counting range clips into `clipped` (see
    /// [`QFormat::quantize_counted`]).
    pub fn quantize_slice_counted(&self, xs: &[f32], clipped: &mut usize) -> Vec<i32> {
        xs.iter().map(|&x| self.quantize_counted(x, clipped)).collect()
    }

    /// Dequantize a slice.
    pub fn dequantize_slice(&self, rs: &[i32]) -> Vec<f32> {
        rs.iter().map(|&r| self.dequantize(r)).collect()
    }

    /// Clamp a wide intermediate into the raw range (the saturation
    /// every hardware ALU output applies). Never panics, for any `i64`.
    // clamp guarantees the value is inside the i32-ranged [min_raw, max_raw]
    #[allow(clippy::cast_possible_truncation)]
    pub fn clamp_raw(&self, v: i64) -> i32 {
        v.clamp(self.min_raw() as i64, self.max_raw() as i64) as i32
    }

    /// Like [`QFormat::clamp_raw`], counting saturation events into `sat`.
    // the fall-through `v as i32` runs only after both range comparisons
    // proved v lies inside [min_raw, max_raw]
    #[allow(clippy::cast_possible_truncation)]
    #[inline]
    pub fn clamp_raw_counted(&self, v: i64, sat: &mut usize) -> i32 {
        if v > self.max_raw() as i64 {
            *sat += 1;
            self.max_raw()
        } else if v < (self.min_raw() as i64) {
            *sat += 1;
            self.min_raw()
        } else {
            v as i32
        }
    }

    /// Saturating fixed-point add. Accepts any raw `i32` inputs (even
    /// out-of-range ones) and never panics or wraps.
    pub fn sat_add(&self, a: i32, b: i32) -> i32 {
        self.clamp_raw(a as i64 + b as i64)
    }

    /// Saturating fixed-point multiply with round-half-up output
    /// rounding: `(a * b) / 2^n`, clamped. Accepts any raw `i32` inputs
    /// and never panics or wraps (`i32::MIN * i32::MIN = 2^62` fits the
    /// `i64` intermediate).
    pub fn sat_mul(&self, a: i32, b: i32) -> i32 {
        self.clamp_raw(shift_round(a as i64 * b as i64, self.frac_bits))
    }

    /// Fold a wide MAC accumulator (edge products at scale `2^2n`) plus a
    /// Qm.n bias into a saturated Qm.n word: one rounding shift at the
    /// very end, the way a DSP-block accumulator chain rounds once on
    /// write-back. This is *the* arithmetic contract shared by
    /// [`FixedSparseLayer::forward`] and the cycle-accurate
    /// [`crate::hw::junction::JunctionUnit::feedforward_quantized`] —
    /// both call it, so they agree bit for bit.
    ///
    /// Accumulator headroom: with in-range words (`|raw| <= 2^(m+n)`)
    /// the `i64` accumulator is exact for up to `2^(62 - 2(m+n))` edges
    /// per output — 2^32 edges for the default Q5.10 (m + n = 15), far
    /// beyond any junction in the paper. Formats near the 31-bit word
    /// limit are for scalar arithmetic, not the MAC kernels.
    #[inline]
    pub fn fold_mac(&self, acc: i64, bias_raw: i32, sat: &mut usize) -> i32 {
        self.clamp_raw_counted(
            shift_round(acc + ((bias_raw as i64) << self.frac_bits), self.frac_bits),
            sat,
        )
    }
}

/// ReLU in the raw domain (sign-exact twin of [`crate::nn::relu`]:
/// quantization preserves sign, so relu-then-quantize equals
/// quantize-then-relu).
pub fn relu_raw(xs: &mut [i32]) {
    for v in xs {
        if *v < 0 {
            *v = 0;
        }
    }
}

/// Segments of the sigmoid interpolation table (range [-8, 8], node
/// spacing h = 0.25 — beyond ±8 the sigmoid is within 3.4e-4 of its
/// asymptote, so clamping there costs less than the interpolation error).
const SIGMOID_SEGMENTS: usize = 64;

/// Sigmoid via lookup table + linear interpolation — the activation
/// evaluator of the arXiv:1806.01087 FPGA pipeline. Table nodes are
/// Qm.n-quantized sigmoid values at spacing h = 0.25 over [-8, 8];
/// evaluation is pure fixed-point (one multiply, one rounding shift).
#[derive(Clone, Debug)]
pub struct SigmoidLut {
    fmt: QFormat,
    /// Raw word of the table's left edge (-8.0; exact for m >= 4).
    lo_raw: i32,
    /// Raw word of the right edge (+8.0).
    hi_raw: i32,
    /// Raw width of one segment (h = 0.25 => scale / 4, exact for n >= 2).
    seg_raw: i64,
    /// `n - 2`: dividing by `seg_raw` is this arithmetic shift.
    seg_shift: u32,
    /// Quantized sigmoid at the 65 nodes.
    table: Vec<i32>,
}

impl SigmoidLut {
    /// Build the table for `fmt`. Requires `m >= 4` (the format must
    /// represent ±8, the table's domain) and `n >= 2` (the node spacing
    /// 0.25 must be a whole number of raw units).
    // sigmoid values lie in (0, 1): the f64 → f32 narrowing before
    // quantize is a sub-ULP rounding, never out of range
    #[allow(clippy::cast_possible_truncation)]
    pub fn new(fmt: QFormat) -> SigmoidLut {
        assert!(
            fmt.int_bits >= 4 && fmt.frac_bits >= 2,
            "sigmoid LUT needs m >= 4 and n >= 2, got {fmt}"
        );
        let table = (0..=SIGMOID_SEGMENTS)
            .map(|i| {
                let x = -8.0 + i as f64 * 0.25;
                fmt.quantize((1.0 / (1.0 + (-x).exp())) as f32)
            })
            .collect();
        SigmoidLut {
            fmt,
            lo_raw: fmt.quantize(-8.0),
            hi_raw: fmt.quantize(8.0),
            seg_raw: 1i64 << (fmt.frac_bits - 2),
            seg_shift: fmt.frac_bits - 2,
            table,
        }
    }

    /// The format the table is quantized in.
    pub fn format(&self) -> QFormat {
        self.fmt
    }

    /// Evaluate at a raw Qm.n word: clamp into [-8, 8], pick the segment,
    /// linearly interpolate between its quantized nodes. Output is always
    /// a valid raw word in [0, 2^n] (never saturates).
    // segment index is bounded by SIGMOID_SEGMENTS (fits usize on any
    // target); the interpolated word lies between two i32 table nodes
    #[allow(clippy::cast_possible_truncation)]
    pub fn eval_raw(&self, x: i32) -> i32 {
        let x = x.clamp(self.lo_raw, self.hi_raw);
        let u = (x - self.lo_raw) as i64;
        let i = ((u / self.seg_raw) as usize).min(SIGMOID_SEGMENTS - 1);
        let frac = u - (i as i64) * self.seg_raw;
        let t0 = self.table[i] as i64;
        let t1 = self.table[i + 1] as i64;
        (t0 + shift_round((t1 - t0) * frac, self.seg_shift)) as i32
    }

    /// Evaluate at a real value (quantize, interpolate, dequantize).
    pub fn eval(&self, x: f32) -> f32 {
        self.fmt.dequantize(self.eval_raw(self.fmt.quantize(x)))
    }

    /// Worst-case |LUT sigmoid − exact sigmoid| over the reals:
    ///
    /// - linear interpolation between exact nodes: `h^2/8 · max|σ''|`
    ///   with h = 0.25 and max|σ''| = 1/(6√3) ≈ 0.0962 → ≤ 7.6e-4,
    /// - node quantization: ≤ ulp/2, carried through interpolation,
    /// - interpolation output rounding: ≤ ulp/2,
    /// - input quantization (for [`SigmoidLut::eval`]): ≤ σ'·ulp/2 ≤ ulp/8,
    /// - clamping at ±8: ≤ σ(-8) ≈ 3.4e-4 (inside the first term's slack).
    pub fn max_error(&self) -> f32 {
        7.6e-4 + 1.5 * self.fmt.ulp()
    }
}

/// One junction in compacted fixed-point form: the Qm.n twin of
/// [`SparseLayer`], same CSR geometry, raw `i32` words for weights and
/// biases.
#[derive(Clone, Debug)]
pub struct FixedSparseLayer {
    /// Left (input) layer width.
    pub n_left: usize,
    /// Right (output) layer width.
    pub n_right: usize,
    /// CSR row offsets, len `n_right + 1`.
    pub offsets: Vec<u32>,
    /// Left-neuron index per edge.
    pub idx: Vec<u32>,
    /// Quantized weight per edge (raw Qm.n words — the Fig. 4 weight
    /// memory as the FPGA would actually store it).
    pub wq: Vec<i32>,
    /// Quantized bias per right neuron.
    pub bq: Vec<i32>,
    /// Weights/biases that were *clipped* at the Qm.n range during
    /// quantization. Nonzero means the format lacks headroom for this
    /// model's parameters and the forward error bound does not apply.
    pub clipped: usize,
    /// The fixed-point format of every word in this layer.
    pub fmt: QFormat,
}

impl FixedSparseLayer {
    /// Quantize an f32 compacted layer into `fmt`, recording how many
    /// parameters clipped at the range ends (see
    /// [`FixedSparseLayer::clipped`]).
    pub fn from_f32(layer: &SparseLayer, fmt: QFormat) -> FixedSparseLayer {
        let mut clipped = 0usize;
        let wq = fmt.quantize_slice_counted(&layer.wc, &mut clipped);
        let bq = fmt.quantize_slice_counted(&layer.bias, &mut clipped);
        FixedSparseLayer {
            n_left: layer.n_left,
            n_right: layer.n_right,
            offsets: layer.offsets.clone(),
            idx: layer.idx.clone(),
            wq,
            bq,
            clipped,
            fmt,
        }
    }

    /// Stored edge count.
    pub fn n_edges(&self) -> usize {
        self.idx.len()
    }

    /// Fixed-point FF (eq. 2a): per output, a wide `i64` MAC accumulation
    /// over the edges followed by one [`QFormat::fold_mac`] rounding /
    /// saturation — bit-identical to the cycle-accurate
    /// [`crate::hw::junction::JunctionUnit::feedforward_quantized`].
    /// Batch rows chunk across the [`parallel`] pool like the f32 kernel.
    /// Returns the number of saturated outputs.
    pub fn forward(&self, a: &[i32], batch: usize, out: &mut [i32]) -> usize {
        assert_eq!(a.len(), batch * self.n_left);
        assert_eq!(out.len(), batch * self.n_right);
        let work = self.n_edges().max(1);
        let sat = AtomicUsize::new(0);
        parallel::par_rows(out, self.n_right, work, |row0, chunk| {
            let mut local = 0usize;
            for (li, or) in chunk.chunks_mut(self.n_right).enumerate() {
                let bi = row0 + li;
                let ar = &a[bi * self.n_left..(bi + 1) * self.n_left];
                for j in 0..self.n_right {
                    let (lo, hi) = (self.offsets[j] as usize, self.offsets[j + 1] as usize);
                    let mut acc = 0i64;
                    for e in lo..hi {
                        acc += self.wq[e] as i64 * ar[self.idx[e] as usize] as i64;
                    }
                    or[j] = self.fmt.fold_mac(acc, self.bq[j], &mut local);
                }
            }
            if local > 0 {
                sat.fetch_add(local, Ordering::Relaxed);
            }
        });
        sat.load(Ordering::Relaxed)
    }

    /// Fixed-point FF with a run-time activation mask: edges whose left
    /// neuron is inactive are skipped inside the same CSR loop as
    /// [`FixedSparseLayer::forward`]. The `i64` accumulation is exact,
    /// so an all-ones mask is bit-identical regardless of order; a
    /// sparse mask does `density * |W_i|` MACs. Returns saturated
    /// outputs.
    pub fn forward_masked(&self, a: &[i32], batch: usize, active: &[bool], out: &mut [i32]) -> usize {
        assert_eq!(a.len(), batch * self.n_left);
        assert_eq!(active.len(), batch * self.n_left);
        assert_eq!(out.len(), batch * self.n_right);
        let work = self.n_edges().max(1);
        let sat = AtomicUsize::new(0);
        parallel::par_rows(out, self.n_right, work, |row0, chunk| {
            let mut local = 0usize;
            for (li, or) in chunk.chunks_mut(self.n_right).enumerate() {
                let bi = row0 + li;
                let ar = &a[bi * self.n_left..(bi + 1) * self.n_left];
                let mr = &active[bi * self.n_left..(bi + 1) * self.n_left];
                for j in 0..self.n_right {
                    let (lo, hi) = (self.offsets[j] as usize, self.offsets[j + 1] as usize);
                    let mut acc = 0i64;
                    for e in lo..hi {
                        let k = self.idx[e] as usize;
                        if !mr[k] {
                            continue;
                        }
                        acc += self.wq[e] as i64 * ar[k] as i64;
                    }
                    or[j] = self.fmt.fold_mac(acc, self.bq[j], &mut local);
                }
            }
            if local > 0 {
                sat.fetch_add(local, Ordering::Relaxed);
            }
        });
        sat.load(Ordering::Relaxed)
    }

    /// Fixed-point BP (eq. 3b inner sum): scatter `wq · delta` into wide
    /// per-left-neuron accumulators, one rounding shift per output.
    /// Caller applies the activation-derivative product (for ReLU that is
    /// a sign mask, exact in either domain). Returns saturated outputs.
    pub fn backprop(&self, delta: &[i32], batch: usize, out: &mut [i32]) -> usize {
        assert_eq!(delta.len(), batch * self.n_right);
        assert_eq!(out.len(), batch * self.n_left);
        let work = self.n_edges().max(1);
        let sat = AtomicUsize::new(0);
        parallel::par_rows(out, self.n_left, work, |row0, chunk| {
            let mut local = 0usize;
            let mut accs = vec![0i64; self.n_left];
            for (li, or) in chunk.chunks_mut(self.n_left).enumerate() {
                let bi = row0 + li;
                let dr = &delta[bi * self.n_right..(bi + 1) * self.n_right];
                accs.fill(0);
                for j in 0..self.n_right {
                    let dv = dr[j] as i64;
                    if dv == 0 {
                        continue;
                    }
                    let (lo, hi) = (self.offsets[j] as usize, self.offsets[j + 1] as usize);
                    for e in lo..hi {
                        accs[self.idx[e] as usize] += self.wq[e] as i64 * dv;
                    }
                }
                for (o, &acc) in or.iter_mut().zip(&accs) {
                    *o = self
                        .fmt
                        .clamp_raw_counted(shift_round(acc, self.fmt.frac_bits), &mut local);
                }
            }
            if local > 0 {
                sat.fetch_add(local, Ordering::Relaxed);
            }
        });
        sat.load(Ordering::Relaxed)
    }

    /// Fixed-point BP with a run-time activation mask: no gradient is
    /// accumulated for inactive left neurons (their zeroed activations
    /// contributed nothing forward). All-ones mask is bit-identical to
    /// [`FixedSparseLayer::backprop`]. Returns saturated outputs.
    pub fn backprop_masked(
        &self,
        delta: &[i32],
        batch: usize,
        active: &[bool],
        out: &mut [i32],
    ) -> usize {
        assert_eq!(delta.len(), batch * self.n_right);
        assert_eq!(active.len(), batch * self.n_left);
        assert_eq!(out.len(), batch * self.n_left);
        let work = self.n_edges().max(1);
        let sat = AtomicUsize::new(0);
        parallel::par_rows(out, self.n_left, work, |row0, chunk| {
            let mut local = 0usize;
            let mut accs = vec![0i64; self.n_left];
            for (li, or) in chunk.chunks_mut(self.n_left).enumerate() {
                let bi = row0 + li;
                let dr = &delta[bi * self.n_right..(bi + 1) * self.n_right];
                let mr = &active[bi * self.n_left..(bi + 1) * self.n_left];
                accs.fill(0);
                for j in 0..self.n_right {
                    let dv = dr[j] as i64;
                    if dv == 0 {
                        continue;
                    }
                    let (lo, hi) = (self.offsets[j] as usize, self.offsets[j + 1] as usize);
                    for e in lo..hi {
                        let k = self.idx[e] as usize;
                        if !mr[k] {
                            continue;
                        }
                        accs[k] += self.wq[e] as i64 * dv;
                    }
                }
                for (o, &acc) in or.iter_mut().zip(&accs) {
                    *o = self
                        .fmt
                        .clamp_raw_counted(shift_round(acc, self.fmt.frac_bits), &mut local);
                }
            }
            if local > 0 {
                sat.fetch_add(local, Ordering::Relaxed);
            }
        });
        sat.load(Ordering::Relaxed)
    }

    /// Fixed-point UP gradients (eq. 4b): `gwq[e] = Σ_b delta·a` (rounded
    /// once), `gbq[j] = Σ_b delta` (already at scale n). No L2 term — the
    /// hardware's plain SGD gradient. Returns saturated outputs.
    pub fn grads(
        &self,
        a: &[i32],
        delta: &[i32],
        batch: usize,
        gwq: &mut [i32],
        gbq: &mut [i32],
    ) -> usize {
        assert_eq!(a.len(), batch * self.n_left);
        assert_eq!(delta.len(), batch * self.n_right);
        assert_eq!(gwq.len(), self.wq.len());
        assert_eq!(gbq.len(), self.n_right);
        let mut acc_w = vec![0i64; self.wq.len()];
        let mut acc_b = vec![0i64; self.n_right];
        for bi in 0..batch {
            let ar = &a[bi * self.n_left..(bi + 1) * self.n_left];
            let dr = &delta[bi * self.n_right..(bi + 1) * self.n_right];
            for j in 0..self.n_right {
                let dv = dr[j] as i64;
                if dv == 0 {
                    continue;
                }
                acc_b[j] += dv;
                let (lo, hi) = (self.offsets[j] as usize, self.offsets[j + 1] as usize);
                for e in lo..hi {
                    acc_w[e] += dv * ar[self.idx[e] as usize] as i64;
                }
            }
        }
        let mut sat = 0usize;
        for (g, &acc) in gwq.iter_mut().zip(&acc_w) {
            *g = self
                .fmt
                .clamp_raw_counted(shift_round(acc, self.fmt.frac_bits), &mut sat);
        }
        for (g, &acc) in gbq.iter_mut().zip(&acc_b) {
            *g = self.fmt.clamp_raw_counted(acc, &mut sat);
        }
        sat
    }

    /// Fixed-point UP gradients with a run-time activation mask: edge
    /// accumulations whose left activation the mask dropped are
    /// skipped; bias gradients are unaffected (constant-1 input).
    /// All-ones mask is bit-identical to [`FixedSparseLayer::grads`].
    /// Returns saturated outputs.
    pub fn grads_masked(
        &self,
        a: &[i32],
        delta: &[i32],
        batch: usize,
        active: &[bool],
        gwq: &mut [i32],
        gbq: &mut [i32],
    ) -> usize {
        assert_eq!(a.len(), batch * self.n_left);
        assert_eq!(delta.len(), batch * self.n_right);
        assert_eq!(active.len(), batch * self.n_left);
        assert_eq!(gwq.len(), self.wq.len());
        assert_eq!(gbq.len(), self.n_right);
        let mut acc_w = vec![0i64; self.wq.len()];
        let mut acc_b = vec![0i64; self.n_right];
        for bi in 0..batch {
            let ar = &a[bi * self.n_left..(bi + 1) * self.n_left];
            let mr = &active[bi * self.n_left..(bi + 1) * self.n_left];
            let dr = &delta[bi * self.n_right..(bi + 1) * self.n_right];
            for j in 0..self.n_right {
                let dv = dr[j] as i64;
                if dv == 0 {
                    continue;
                }
                acc_b[j] += dv;
                let (lo, hi) = (self.offsets[j] as usize, self.offsets[j + 1] as usize);
                for e in lo..hi {
                    let k = self.idx[e] as usize;
                    if !mr[k] {
                        continue;
                    }
                    acc_w[e] += dv * ar[k] as i64;
                }
            }
        }
        let mut sat = 0usize;
        for (g, &acc) in gwq.iter_mut().zip(&acc_w) {
            *g = self
                .fmt
                .clamp_raw_counted(shift_round(acc, self.fmt.frac_bits), &mut sat);
        }
        for (g, &acc) in gbq.iter_mut().zip(&acc_b) {
            *g = self.fmt.clamp_raw_counted(acc, &mut sat);
        }
        sat
    }
}

/// Build an [`ActivationMask`] from *raw* Qm.n activations. Selection
/// on raw magnitudes matches selection on dequantized values exactly —
/// the scale `2^n` is positive and uniform, so the magnitude order is
/// identical — and stays pure integer arithmetic (what a hardware
/// top-k selector would compare). Top-k ties break toward the lower
/// index, as in [`ActivationMask::top_k`].
pub fn mask_raw(
    spec: &ActSpec,
    acts: &[i32],
    n: usize,
    batch: usize,
    fmt: QFormat,
    stamp: u64,
) -> ActivationMask {
    assert_eq!(acts.len(), n * batch, "activation buffer shape");
    let mut active = vec![false; n * batch];
    match spec.mode {
        ActMode::TopK(k) => {
            if k >= n {
                active.fill(true);
            } else {
                let mut order: Vec<usize> = Vec::with_capacity(n);
                for r in 0..batch {
                    let row = &acts[r * n..(r + 1) * n];
                    order.clear();
                    order.extend(0..n);
                    order.sort_unstable_by(|&ia, &ib| {
                        let (ma, mb) = ((row[ia] as i64).abs(), (row[ib] as i64).abs());
                        mb.cmp(&ma).then(ia.cmp(&ib))
                    });
                    for &i in &order[..k] {
                        active[r * n + i] = true;
                    }
                }
            }
        }
        ActMode::Threshold(t) => {
            let t_raw = (fmt.quantize(t) as i64).abs();
            for (m, &v) in active.iter_mut().zip(acts) {
                *m = (v as i64).abs() >= t_raw;
            }
        }
    }
    ActivationMask { n, batch, active, stamp }
}

/// Whole-network fixed-point MLP: the Qm.n twin of [`SparseNet`].
#[derive(Clone, Debug)]
pub struct FixedSparseNet {
    /// Neuronal configuration `[N_0, ..., N_L]`.
    pub layers: Vec<usize>,
    /// One quantized compacted layer per junction.
    pub junctions: Vec<FixedSparseLayer>,
    /// The shared fixed-point format.
    pub fmt: QFormat,
}

impl FixedSparseNet {
    /// Quantize a trained (or initialized) f32 compacted net.
    pub fn from_f32(net: &SparseNet, fmt: QFormat) -> FixedSparseNet {
        FixedSparseNet {
            layers: net.layers.clone(),
            junctions: net
                .junctions
                .iter()
                .map(|j| FixedSparseLayer::from_f32(j, fmt))
                .collect(),
            fmt,
        }
    }

    /// Total stored edges.
    pub fn n_edges(&self) -> usize {
        self.junctions.iter().map(|j| j.n_edges()).sum()
    }

    /// Parameters that clipped at the Qm.n range during quantization,
    /// across every junction. Nonzero voids the forward error bound
    /// (its |Δw| ≤ ulp/2 premise), so callers surface it next to the
    /// runtime saturation count instead of treating the net as sound.
    pub fn clipped_params(&self) -> usize {
        self.junctions.iter().map(|j| j.clipped).sum()
    }

    /// Fixed-point inference on raw inputs: returns raw logits
    /// `[batch, N_L]` and the total saturated outputs across all layers.
    pub fn logits_q(&self, xq: &[i32], batch: usize) -> (Vec<i32>, usize) {
        let mut a = xq.to_vec();
        let l = self.junctions.len();
        let mut sats = 0usize;
        for (i, junction) in self.junctions.iter().enumerate() {
            let mut h = vec![0i32; batch * junction.n_right];
            sats += junction.forward(&a, batch, &mut h);
            if i != l - 1 {
                relu_raw(&mut h);
            }
            a = h;
        }
        (a, sats)
    }

    /// Real-valued convenience: quantize the input, run fixed-point,
    /// dequantize the logits. Returns (logits, saturated outputs).
    pub fn logits(&self, x: &[f32], batch: usize) -> (Vec<f32>, usize) {
        let (raw, sats) = self.logits_q(&self.fmt.quantize_slice(x), batch);
        (self.fmt.dequantize_slice(&raw), sats)
    }

    /// Sparse-sparse fixed-point inference: every hidden layer's raw
    /// activations go through `spec`'s selection (via [`mask_raw`],
    /// identical ordering to the f32 selection) and the masked kernel
    /// skips the dropped neurons. Returns raw logits, saturated
    /// outputs, and the achieved activation-density tally. An
    /// all-keeping spec reproduces [`FixedSparseNet::logits_q`] bit for
    /// bit (`i64` accumulation is exact, order-independent).
    pub fn logits_q_act(
        &self,
        xq: &[i32],
        batch: usize,
        spec: &ActSpec,
    ) -> (Vec<i32>, usize, ActStats) {
        let mut a = xq.to_vec();
        let l = self.junctions.len();
        let mut sats = 0usize;
        let mut stats = ActStats::default();
        for (i, junction) in self.junctions.iter().enumerate() {
            let mut h = vec![0i32; batch * junction.n_right];
            if i == 0 {
                sats += junction.forward(&a, batch, &mut h);
            } else {
                let m = mask_raw(spec, &a, junction.n_left, batch, self.fmt, 0);
                stats.merge(m.stats());
                sats += junction.forward_masked(&a, batch, &m.active, &mut h);
            }
            if i != l - 1 {
                relu_raw(&mut h);
            }
            a = h;
        }
        (a, sats, stats)
    }

    /// Real-valued convenience over [`FixedSparseNet::logits_q_act`].
    pub fn logits_act(
        &self,
        x: &[f32],
        batch: usize,
        spec: &ActSpec,
    ) -> (Vec<f32>, usize, ActStats) {
        let (raw, sats, stats) = self.logits_q_act(&self.fmt.quantize_slice(x), batch, spec);
        (self.fmt.dequantize_slice(&raw), sats, stats)
    }

    /// Classification accuracy under an activation-sparsity spec (the
    /// equal-accuracy axis of the sparse-sparse benches).
    pub fn accuracy_act(&self, x: &[f32], y: &[i32], spec: &ActSpec) -> f64 {
        let batch = y.len();
        let classes = *self.layers.last().unwrap();
        let (logits, _, _) = self.logits_q_act(&self.fmt.quantize_slice(x), batch, spec);
        let mut correct = 0usize;
        for i in 0..batch {
            let row = &logits[i * classes..(i + 1) * classes];
            let mut best = 0usize;
            for (c, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = c;
                }
            }
            if best == y[i] as usize {
                correct += 1;
            }
        }
        correct as f64 / batch.max(1) as f64
    }

    /// (correct argmax predictions, saturated outputs) over one batch —
    /// argmax is taken on raw words (order-preserving, no dequantization
    /// needed, exactly what a hardware classifier head would do).
    pub fn eval_batch(&self, x: &[f32], y: &[i32]) -> (usize, usize) {
        let batch = y.len();
        let classes = *self.layers.last().unwrap();
        let (logits, sats) = self.logits_q(&self.fmt.quantize_slice(x), batch);
        let mut correct = 0usize;
        for i in 0..batch {
            let row = &logits[i * classes..(i + 1) * classes];
            let mut best = 0usize;
            for (c, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = c;
                }
            }
            if best == y[i] as usize {
                correct += 1;
            }
        }
        (correct, sats)
    }

    /// Classification accuracy over one batch (fixed-point end to end).
    pub fn accuracy(&self, x: &[f32], y: &[i32]) -> f64 {
        let (correct, _) = self.eval_batch(x, y);
        correct as f64 / y.len().max(1) as f64
    }
}

/// Worst-case |dequantized quantized forward − f32 forward| for `net` on
/// the concrete input `x`, under `fmt` — the bound `tests/prop_fixed.rs`
/// enforces. Per layer (derivation in ARCHITECTURE.md §Fixed-point
/// arithmetic; u = ulp, ε_in = incoming activation error):
///
/// ```text
/// ε_out = d_in_max · (w_max·ε_in + (a_max + ε_in)·u/2) + u
/// ```
///
/// where the trailing `u` covers bias quantization (u/2) plus the single
/// MAC rounding shift (u/2); ε_in starts at u/2 (input quantization) and
/// ReLU is 1-Lipschitz so the bound passes through activations
/// unchanged. Valid only when no saturation occurred (the tests assert
/// the saturation count is zero first). `a_max`/`w_max` are measured on
/// the f32 reference, so the bound is input-specific, not a worst case
/// over all inputs.
// the accumulated f64 bound is tiny (fractions of the activation scale)
// whenever the premises hold, so the final f32 narrowing is a rounding
#[allow(clippy::cast_possible_truncation)]
pub fn forward_error_bound(net: &SparseNet, x: &[f32], batch: usize, fmt: QFormat) -> f32 {
    let u = fmt.ulp() as f64;
    let mut err = 0.5 * u;
    let l = net.junctions.len();
    let mut a = x.to_vec();
    for (i, junction) in net.junctions.iter().enumerate() {
        let amax = a.iter().fold(0f32, |m, v| m.max(v.abs())) as f64;
        let wmax = junction.wc.iter().fold(0f32, |m, v| m.max(v.abs())) as f64;
        let din_max = (0..junction.n_right)
            .map(|j| (junction.offsets[j + 1] - junction.offsets[j]) as usize)
            .max()
            .unwrap_or(0) as f64;
        err = din_max * (wmax * err + (amax + err) * 0.5 * u) + u;
        let mut h = vec![0f32; batch * junction.n_right];
        junction.forward(&a, batch, &mut h);
        if i != l - 1 {
            crate::nn::relu(&mut h);
        }
        a = h;
    }
    // small multiplicative + absolute slack for the f32 reference's own
    // rounding (the bound above treats the f32 path as exact)
    (err * 1.001 + 1e-5) as f32
}

#[cfg(test)]
// test fixtures cast freely between numeric types on hand-picked values
#[allow(clippy::cast_possible_truncation, clippy::lossy_float_literal)]
mod tests {
    use super::*;
    use crate::sparsity::config::{DoutConfig, NetConfig};
    use crate::sparsity::{generate, Method};
    use crate::util::rng::Rng;

    #[test]
    fn format_ranges_and_parse() {
        let q = QFormat::new(4, 12);
        assert_eq!(q.word_bits(), 17);
        assert_eq!(q.max_raw(), (1 << 16) - 1);
        assert_eq!(q.min_raw(), -(1 << 16));
        assert!((q.ulp() - 1.0 / 4096.0).abs() < 1e-12);
        assert_eq!(QFormat::parse("Q4.12"), Some(q));
        assert_eq!(QFormat::parse("q4.12"), Some(q));
        assert_eq!(QFormat::parse(" Q5.10 "), Some(QFormat::default()));
        assert_eq!(QFormat::parse("4.12"), None);
        assert_eq!(QFormat::parse("Q40.12"), None);
        assert_eq!(QFormat::parse("Qx.y"), None);
        assert!(QFormat::new_checked(0, 0).is_none());
        assert!(QFormat::new_checked(15, 16).is_some());
        assert!(QFormat::new_checked(16, 16).is_none());
        assert_eq!(format!("{}", QFormat::default()), "Q5.10");
    }

    #[test]
    fn quantize_saturates_and_handles_non_finite() {
        let q = QFormat::new(3, 8);
        assert_eq!(q.quantize(1000.0), q.max_raw());
        assert_eq!(q.quantize(-1000.0), q.min_raw());
        assert_eq!(q.quantize(f32::INFINITY), q.max_raw());
        assert_eq!(q.quantize(f32::NEG_INFINITY), q.min_raw());
        assert_eq!(q.quantize(f32::NAN), 0);
        assert_eq!(q.quantize(0.0), 0);
        // exact grid points are exact
        assert_eq!(q.quantize(1.5), 384);
        assert_eq!(q.dequantize(384), 1.5);
    }

    #[test]
    fn sat_ops_clamp_without_wrapping() {
        let q = QFormat::new(4, 8);
        assert_eq!(q.sat_add(q.max_raw(), 1), q.max_raw());
        assert_eq!(q.sat_add(q.min_raw(), -1), q.min_raw());
        assert_eq!(q.sat_add(i32::MAX, i32::MAX), q.max_raw());
        assert_eq!(q.sat_mul(i32::MIN, i32::MIN), q.max_raw());
        assert_eq!(q.sat_mul(i32::MIN, i32::MAX), q.min_raw());
        // in-range product is the rounded real product
        let a = q.quantize(1.25);
        let b = q.quantize(-2.5);
        assert_eq!(q.sat_mul(a, b), q.quantize(-3.125));
    }

    #[test]
    fn shift_round_rounds_half_up() {
        assert_eq!(shift_round(5, 1), 3); // 2.5 -> 3
        assert_eq!(shift_round(-5, 1), -2); // -2.5 -> -2 (toward +inf)
        assert_eq!(shift_round(4, 2), 1);
        assert_eq!(shift_round(7, 0), 7);
    }

    /// Pins the static verifier's `i128` rounding shift
    /// ([`crate::analysis::range::shift_round_wide`]) to the execution
    /// kernels' `shift_round` on the shared `i64` domain — the range
    /// analysis in `analysis::range` is only sound if the two agree on
    /// every value the kernels can produce.
    #[test]
    fn shift_round_wide_agrees_with_kernel_rounding() {
        use crate::analysis::range::shift_round_wide;
        // cover signs, ties, zero, and magnitudes up to the MAC
        // accumulator headroom (|acc| <= 2^62 per the fold_mac contract;
        // shift_round itself needs |v| + 2^(n-1) to fit i64)
        let samples: [i64; 12] = [
            0,
            1,
            -1,
            5,
            -5,
            255,
            -256,
            (1 << 20) + 3,
            -(1 << 20) - 3,
            (1 << 62) - 1,
            -(1 << 62),
            0x1812_0116,
        ];
        let mut rng = Rng::new(0x1812);
        for n in [0u32, 1, 2, 5, 10, 15, 31] {
            for &v in &samples {
                assert_eq!(
                    shift_round_wide(v as i128, n),
                    shift_round(v, n) as i128,
                    "divergence at v={v} n={n}"
                );
            }
            for _ in 0..200 {
                let v = (rng.next_u64() as i64) >> 2; // |v| <= 2^61: no overflow
                assert_eq!(
                    shift_round_wide(v as i128, n),
                    shift_round(v, n) as i128,
                    "divergence at v={v} n={n}"
                );
            }
        }
    }

    #[test]
    fn sigmoid_lut_tracks_reference_within_bound() {
        for fmt in [QFormat::default(), QFormat::new(4, 12), QFormat::new(6, 8)] {
            let lut = SigmoidLut::new(fmt);
            let bound = lut.max_error();
            let mut x = -12.0f32;
            while x <= 12.0 {
                let want = 1.0 / (1.0 + (-x as f64).exp());
                let got = lut.eval(x) as f64;
                assert!(
                    (got - want).abs() <= bound as f64,
                    "{fmt} at x={x}: {got} vs {want} (bound {bound})"
                );
                x += 0.0173;
            }
        }
    }

    #[test]
    fn sigmoid_lut_is_monotone_and_bounded() {
        let lut = SigmoidLut::new(QFormat::default());
        let scale = QFormat::default().scale() as i32;
        let mut prev = i32::MIN;
        for raw in (-9 * scale..=9 * scale).step_by(37) {
            let y = lut.eval_raw(raw);
            assert!((0..=scale).contains(&y), "sigmoid out of [0,1]: {y}");
            assert!(y >= prev, "sigmoid not monotone at raw {raw}");
            prev = y;
        }
    }

    fn toy_nets(seed: u64) -> (SparseNet, FixedSparseNet, Vec<f32>) {
        let netc = NetConfig::new(vec![20, 12, 6]);
        let mut rng = Rng::new(seed);
        let pattern = generate(
            Method::Structured,
            &netc,
            &DoutConfig(vec![6, 3]),
            None,
            &mut rng,
        );
        let snet = SparseNet::init_he(&pattern, 0.1, &mut rng);
        let fmt = QFormat::default();
        let qnet = FixedSparseNet::from_f32(&snet, fmt);
        let x: Vec<f32> = (0..8 * 20).map(|_| rng.uniform() * 2.0 - 1.0).collect();
        (snet, qnet, x)
    }

    #[test]
    fn quantized_forward_tracks_f32_within_bound() {
        let (snet, qnet, x) = toy_nets(1);
        let want = snet.logits(&x, 8);
        let (got, sats) = qnet.logits(&x, 8);
        assert_eq!(sats, 0, "toy net must not saturate");
        let bound = forward_error_bound(&snet, &x, 8, qnet.fmt);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() <= bound, "{g} vs {w} (bound {bound})");
        }
    }

    #[test]
    fn backprop_and_grads_track_f32() {
        let (snet, qnet, _) = toy_nets(2);
        let fmt = qnet.fmt;
        let mut rng = Rng::new(3);
        let batch = 4;
        let j = &snet.junctions[0];
        let jq = &qnet.junctions[0];
        let a: Vec<f32> = (0..batch * j.n_left).map(|_| rng.uniform() * 2.0 - 1.0).collect();
        let d: Vec<f32> = (0..batch * j.n_right).map(|_| rng.uniform() - 0.5).collect();

        let mut da = vec![0f32; batch * j.n_left];
        j.backprop(&d, batch, &mut da);
        let mut daq = vec![0i32; batch * j.n_left];
        let sat = jq.backprop(&fmt.quantize_slice(&d), batch, &mut daq);
        assert_eq!(sat, 0);
        for (g, w) in fmt.dequantize_slice(&daq).iter().zip(&da) {
            // loose envelope: d_in quantized products, each within ~u
            assert!((g - w).abs() < 32.0 * fmt.ulp(), "{g} vs {w}");
        }

        let mut gw = vec![0f32; j.wc.len()];
        let mut gb = vec![0f32; j.n_right];
        j.grads(&a, &d, batch, 0.0, &mut gw, &mut gb);
        let mut gwq = vec![0i32; j.wc.len()];
        let mut gbq = vec![0i32; j.n_right];
        let sat = jq.grads(
            &fmt.quantize_slice(&a),
            &fmt.quantize_slice(&d),
            batch,
            &mut gwq,
            &mut gbq,
        );
        assert_eq!(sat, 0);
        for (g, w) in fmt.dequantize_slice(&gwq).iter().zip(&gw) {
            assert!((g - w).abs() < 16.0 * fmt.ulp(), "{g} vs {w}");
        }
        for (g, w) in fmt.dequantize_slice(&gbq).iter().zip(&gb) {
            assert!((g - w).abs() < 16.0 * fmt.ulp(), "{g} vs {w}");
        }
    }

    #[test]
    fn all_ones_mask_is_bit_exact_in_fixed_point() {
        use crate::nn::actsparse::ActSpec;
        let (_, qnet, x) = toy_nets(5);
        let xq = qnet.fmt.quantize_slice(&x);
        let (want, sats_w) = qnet.logits_q(&xq, 8);
        let keep_all = ActSpec::top_k(usize::MAX);
        let (got, sats_g, stats) = qnet.logits_q_act(&xq, 8, &keep_all);
        assert_eq!(got, want, "all-keeping spec must be raw-word identical");
        assert_eq!(sats_g, sats_w);
        assert!((stats.density() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn raw_mask_selection_matches_dequantized_selection() {
        use crate::nn::actsparse::{ActSpec, ActivationMask};
        let fmt = QFormat::default();
        let vals = [0.75f32, -0.5, 0.25, -1.5, 0.0, 0.5];
        let raw = fmt.quantize_slice(&vals);
        for k in 0..=6 {
            let spec = ActSpec::top_k(k);
            let mr = mask_raw(&spec, &raw, 6, 1, fmt, 0);
            let mf = ActivationMask::top_k(&vals, 6, 1, k, 0);
            assert_eq!(mr.active, mf.active, "k = {k}");
        }
        let spec = ActSpec::threshold(0.5);
        let mr = mask_raw(&spec, &raw, 6, 1, fmt, 0);
        let mf = ActivationMask::threshold(&vals, 6, 1, 0.5, 0);
        assert_eq!(mr.active, mf.active);
    }

    #[test]
    fn masked_fixed_kernels_skip_inactive_terms() {
        let fmt = QFormat::default();
        let layer = SparseLayer {
            n_left: 4,
            n_right: 2,
            offsets: vec![0, 2, 4],
            idx: vec![0, 1, 2, 3],
            wc: vec![1.0, 1.0, 1.0, 1.0],
            bias: vec![0.0, 0.0],
        };
        let q = FixedSparseLayer::from_f32(&layer, fmt);
        let a = fmt.quantize_slice(&[1.0, 2.0, 3.0, 4.0]);
        let active = [true, false, true, false];
        let mut out = vec![0i32; 2];
        assert_eq!(q.forward_masked(&a, 1, &active, &mut out), 0);
        assert_eq!(out, vec![fmt.quantize(1.0), fmt.quantize(3.0)]);
        // BP: only active left neurons receive gradient
        let d = fmt.quantize_slice(&[1.0, 1.0]);
        let mut da = vec![0i32; 4];
        assert_eq!(q.backprop_masked(&d, 1, &active, &mut da), 0);
        assert_eq!(da, vec![fmt.quantize(1.0), 0, fmt.quantize(1.0), 0]);
        // UP: inactive edges accumulate nothing, bias grads unaffected
        let mut gw = vec![0i32; 4];
        let mut gb = vec![0i32; 2];
        assert_eq!(q.grads_masked(&a, &d, 1, &active, &mut gw, &mut gb), 0);
        assert_eq!(gw, vec![fmt.quantize(1.0), 0, fmt.quantize(3.0), 0]);
        assert_eq!(gb, fmt.quantize_slice(&[1.0, 1.0]));
    }

    #[test]
    fn quantization_clips_are_counted() {
        let q = QFormat::new(3, 8); // range ±8
        let mut clipped = 0usize;
        // in-range values (range ends included) are not clips
        assert_eq!(q.quantize_counted(1.0, &mut clipped), 256);
        assert_eq!(q.quantize_counted(q.max_value(), &mut clipped), q.max_raw());
        assert_eq!(q.quantize_counted(-8.0, &mut clipped), q.min_raw());
        assert_eq!(clipped, 0);
        // out-of-range and non-finite values count
        q.quantize_counted(100.0, &mut clipped);
        q.quantize_counted(-100.0, &mut clipped);
        q.quantize_counted(f32::NAN, &mut clipped);
        assert_eq!(clipped, 3);
        // layer ingest records parameter clips
        let layer = SparseLayer {
            n_left: 2,
            n_right: 1,
            offsets: vec![0, 2],
            idx: vec![0, 1],
            wc: vec![0.5, 40.0], // second weight clips at ±8
            bias: vec![0.0],
        };
        let fq = FixedSparseLayer::from_f32(&layer, q);
        assert_eq!(fq.clipped, 1);
    }

    #[test]
    fn saturation_is_counted_not_panicked() {
        // weights/inputs at the format maximum force accumulator overflow
        let fmt = QFormat::new(2, 6); // tiny range ±4
        let layer = SparseLayer {
            n_left: 4,
            n_right: 2,
            offsets: vec![0, 4, 8],
            idx: vec![0, 1, 2, 3, 0, 1, 2, 3],
            wc: vec![3.9; 8],
            bias: vec![0.0, 0.0],
        };
        let q = FixedSparseLayer::from_f32(&layer, fmt);
        let a = vec![fmt.max_raw(); 4];
        let mut out = vec![0i32; 2];
        let sats = q.forward(&a, 1, &mut out);
        assert_eq!(sats, 2, "both outputs must saturate");
        assert!(out.iter().all(|&v| v == fmt.max_raw()));
    }

    #[test]
    fn accuracy_matches_f32_on_separable_toy() {
        let (snet, qnet, x) = toy_nets(4);
        let y: Vec<i32> = (0..8).map(|i| (i % 6) as i32).collect();
        let af = snet.accuracy(&x, &y);
        let aq = qnet.accuracy(&x, &y);
        // logits differ by less than the bound, so argmax flips are rare;
        // allow one flip on the 8-sample toy batch
        assert!((af - aq).abs() <= 0.125 + 1e-9, "{af} vs {aq}");
    }
}
