//! Static verification of model-level properties (`pds analyze`).
//!
//! The runtime already *observes* the hardware contracts dynamically —
//! `sparsity::clash_free` replays schedules, `hw::banked` audits concrete
//! weights, and the Qm.n kernels count saturations after the fact. This
//! module instead *proves* the same properties from structure alone, with
//! no training data and no execution, the way arXiv:1806.01087 treats
//! clash-freedom as a design-time proof obligation:
//!
//! - [`clash`] — the clash-freedom prover: per-junction symbolic proof
//!   over the address-generator state ([`crate::sparsity::clash_free::ScheduleSpec`]),
//!   the eq. 9 / Appendix B z-net constraints, and the closed-form
//!   FF/BP/UP pipeline interleave of `hw::pipeline`, valid for *all*
//!   cycles — with a typed counterexample (junction / cycle / bank) on
//!   failure.
//! - [`range`] — quantization range analysis: interval propagation
//!   through the Qm.n dataflow bounding every activation and wide MAC
//!   accumulator, proving saturation unreachable for a given input range
//!   (or reporting the first junction where the bound breaks, the
//!   certified safe input range, and the minimal Qm.n that would fix it).
//! - [`lint`] — manifest lint: degenerate layers/batches, inadmissible
//!   out-degrees, duplicate tensors, shape mismatches, unknown fields
//!   and entries the parser would silently drop.
//!
//! Every pass emits typed, machine-readable [`Finding`]s graded by
//! [`Severity`]; [`AnalysisReport::to_json`] is the stable `--json`
//! surface (schema-checked by `tests/bench_meta.rs`). The cheap lint
//! pass also runs at load time ([`crate::runtime::Manifest::load_or_builtin`]
//! gates on it; [`crate::runtime::Engine::from_manifest`] asserts it), so
//! a structurally broken manifest never reaches a worker thread.

pub mod clash;
pub mod lint;
pub mod range;

use std::collections::BTreeMap;

use crate::nn::fixed::QFormat;
use crate::runtime::manifest::{ConfigEntry, Manifest};
use crate::util::json::Json;

/// Severity grade of a [`Finding`]. Ordered most severe first, so a
/// plain sort puts errors at the top of a report.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    /// A proved violation: `pds analyze` exits nonzero.
    Error,
    /// Suspicious but not a proved violation.
    Warning,
    /// A positive result (what was proved) or a skipped pass.
    Info,
}

impl Severity {
    /// Machine-readable name (`error` / `warning` / `info`).
    pub fn name(&self) -> &'static str {
        match self {
            Severity::Error => "error",
            Severity::Warning => "warning",
            Severity::Info => "info",
        }
    }
}

/// One typed analyzer finding. `junction` / `cycle` / `bank` /
/// `context` carry the counterexample coordinates when the pass has
/// them (the clash prover always points at the offending access; the
/// multi-tenant audit names the offending context).
#[derive(Clone, Debug)]
pub struct Finding {
    /// Emitting pass (`clash`, `range`, `lint`).
    pub pass: &'static str,
    /// Stable machine-readable finding code (e.g. `memory-clash`).
    pub code: &'static str,
    /// Severity grade.
    pub severity: Severity,
    /// Config the finding is about (`<manifest>` for document-level).
    pub config: String,
    /// Human-readable description.
    pub message: String,
    /// Counterexample junction, when the finding has one.
    pub junction: Option<usize>,
    /// Counterexample cycle, when the finding has one.
    pub cycle: Option<usize>,
    /// Counterexample memory bank, when the finding has one.
    pub bank: Option<usize>,
    /// Offending tenant context, when the finding has one.
    pub context: Option<usize>,
}

impl Finding {
    /// A finding with no counterexample coordinates (attach them with
    /// the `with_*` builders).
    pub fn new(
        pass: &'static str,
        code: &'static str,
        severity: Severity,
        config: &str,
        message: String,
    ) -> Finding {
        Finding {
            pass,
            code,
            severity,
            config: config.to_string(),
            message,
            junction: None,
            cycle: None,
            bank: None,
            context: None,
        }
    }

    /// Attach the counterexample junction.
    pub fn with_junction(mut self, j: usize) -> Finding {
        self.junction = Some(j);
        self
    }

    /// Attach the counterexample cycle.
    pub fn with_cycle(mut self, c: usize) -> Finding {
        self.cycle = Some(c);
        self
    }

    /// Attach the counterexample memory bank.
    pub fn with_bank(mut self, b: usize) -> Finding {
        self.bank = Some(b);
        self
    }

    /// Attach the offending tenant context.
    pub fn with_context(mut self, c: usize) -> Finding {
        self.context = Some(c);
        self
    }

    /// The finding as one JSON object (coordinates present only when
    /// the finding carries them).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("pass".to_string(), Json::Str(self.pass.to_string()));
        m.insert("code".to_string(), Json::Str(self.code.to_string()));
        m.insert(
            "severity".to_string(),
            Json::Str(self.severity.name().to_string()),
        );
        m.insert("config".to_string(), Json::Str(self.config.clone()));
        m.insert("message".to_string(), Json::Str(self.message.clone()));
        if let Some(j) = self.junction {
            m.insert("junction".to_string(), Json::Num(j as f64));
        }
        if let Some(c) = self.cycle {
            m.insert("cycle".to_string(), Json::Num(c as f64));
        }
        if let Some(b) = self.bank {
            m.insert("bank".to_string(), Json::Num(b as f64));
        }
        if let Some(c) = self.context {
            m.insert("context".to_string(), Json::Num(c as f64));
        }
        Json::Obj(m)
    }
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "[{:<7}] {} {}/{}: {}",
            self.severity.name(),
            self.config,
            self.pass,
            self.code,
            self.message
        )
    }
}

/// What one `pds analyze` run concluded.
#[derive(Clone, Debug, Default)]
pub struct AnalysisReport {
    /// Every finding, across passes and configs.
    pub findings: Vec<Finding>,
}

impl AnalysisReport {
    /// True when any finding is error-level (`pds analyze` exits nonzero).
    pub fn has_errors(&self) -> bool {
        self.count(Severity::Error) > 0
    }

    /// Number of findings at `severity`.
    pub fn count(&self, severity: Severity) -> usize {
        self.findings.iter().filter(|f| f.severity == severity).count()
    }

    /// Stable-sort findings most severe first (ties keep pass order).
    pub fn sort_by_severity(&mut self) {
        self.findings.sort_by_key(|f| f.severity);
    }

    /// The stable machine-readable report (the `pds analyze --json`
    /// surface; `tests/bench_meta.rs` schema-checks this shape).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("version".to_string(), Json::Num(1.0));
        m.insert(
            "status".to_string(),
            Json::Str(if self.has_errors() { "fail" } else { "pass" }.to_string()),
        );
        m.insert(
            "errors".to_string(),
            Json::Num(self.count(Severity::Error) as f64),
        );
        m.insert(
            "warnings".to_string(),
            Json::Num(self.count(Severity::Warning) as f64),
        );
        m.insert(
            "infos".to_string(),
            Json::Num(self.count(Severity::Info) as f64),
        );
        m.insert(
            "findings".to_string(),
            Json::Arr(self.findings.iter().map(Finding::to_json).collect()),
        );
        Json::Obj(m)
    }
}

impl std::fmt::Display for AnalysisReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for finding in &self.findings {
            writeln!(f, "{finding}")?;
        }
        writeln!(
            f,
            "analysis: {} error(s), {} warning(s), {} info",
            self.count(Severity::Error),
            self.count(Severity::Warning),
            self.count(Severity::Info)
        )
    }
}

/// Knobs of one analyzer run.
#[derive(Clone, Copy, Debug)]
pub struct AnalyzeOptions {
    /// Override the config's Qm.n format for the range analysis
    /// (`None` = use the manifest's quant spec).
    pub quant: Option<QFormat>,
    /// Junction cycles for the pipeline-interleave audit (`None` = the
    /// 4L+2 default; clamped up so the steady state is always covered).
    pub depth: Option<usize>,
    /// Input magnitude the range analysis must *prove* safe (`None` =
    /// certify mode: report the maximal provably safe range instead,
    /// erroring only when none exists).
    pub input_range: Option<f32>,
    /// Seed of the pattern/parameter draw the range analysis inspects
    /// (the clash proof is seed-independent: it holds for every draw).
    pub seed: u64,
    /// Tenant contexts to prove the multi-tenant schedule for (per-context
    /// clash-freedom and the per-context staleness closed form). `1` =
    /// the single-tenant pipeline, exactly today's proof.
    pub contexts: usize,
}

impl Default for AnalyzeOptions {
    fn default() -> Self {
        AnalyzeOptions {
            quant: None,
            depth: None,
            input_range: None,
            seed: 0x1812_0116,
            contexts: 1,
        }
    }
}

/// Run every pass over one config.
pub fn analyze_config(name: &str, entry: &ConfigEntry, opts: &AnalyzeOptions) -> AnalysisReport {
    let mut findings = lint::lint_entry(name, entry);
    // deeper passes build NetConfig / patterns from the entry, which is
    // only meaningful when the structural lint is clean
    if !findings.iter().any(|f| f.severity == Severity::Error) {
        let (clash_findings, _proof) =
            clash::prove_config(name, entry, opts.depth, opts.seed, opts.contexts);
        findings.extend(clash_findings);
        findings.extend(range::analyze_entry(
            name,
            entry,
            opts.quant,
            opts.input_range,
            opts.seed,
        ));
    }
    AnalysisReport { findings }
}

/// Run every pass over every config of a manifest.
pub fn analyze_manifest(manifest: &Manifest, opts: &AnalyzeOptions) -> AnalysisReport {
    let mut report = AnalysisReport::default();
    for (name, entry) in &manifest.configs {
        report
            .findings
            .extend(analyze_config(name, entry, opts).findings);
    }
    report
}

/// The cheap load-time subset: manifest lint only (no pattern draws, no
/// interval propagation) — what [`crate::runtime::Engine::from_manifest`]
/// asserts and [`crate::runtime::Manifest::load_or_builtin`] gates on.
pub fn quick_lint(manifest: &Manifest) -> AnalysisReport {
    AnalysisReport {
        findings: lint::lint_manifest(manifest),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_manifest_analyzes_clean() {
        let report = analyze_manifest(&Manifest::builtin(), &AnalyzeOptions::default());
        assert!(
            !report.has_errors(),
            "builtin configs must prove clean:\n{report}"
        );
        // every config produced a positive clash proof and a range proof
        for name in ["tiny", "mnist_fc2", "mnist_fc4", "timit"] {
            assert!(
                report
                    .findings
                    .iter()
                    .any(|f| f.config == name && f.code == "proved"),
                "{name}: no clash proof"
            );
            assert!(
                report
                    .findings
                    .iter()
                    .any(|f| f.config == name && f.code == "certified-range"),
                "{name}: no certified range"
            );
        }
    }

    #[test]
    fn report_json_shape_and_counts() {
        let mut report = AnalysisReport::default();
        report.findings.push(Finding::new(
            "clash",
            "proved",
            Severity::Info,
            "tiny",
            "ok".into(),
        ));
        report.findings.push(
            Finding::new(
                "clash",
                "memory-clash",
                Severity::Error,
                "tiny",
                "bank hit twice".into(),
            )
            .with_junction(1)
            .with_cycle(4)
            .with_bank(0),
        );
        report.sort_by_severity();
        assert_eq!(report.findings[0].code, "memory-clash");
        assert!(report.has_errors());
        let j = report.to_json();
        assert_eq!(j.get("status").and_then(|v| v.as_str()), Some("fail"));
        assert_eq!(j.get("errors").and_then(|v| v.as_usize()), Some(1));
        let arr = j.get("findings").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("junction").and_then(|v| v.as_usize()), Some(1));
        assert_eq!(arr[0].get("cycle").and_then(|v| v.as_usize()), Some(4));
        assert_eq!(arr[0].get("bank").and_then(|v| v.as_usize()), Some(0));
        // round-trips through the hand-rolled JSON layer
        let reparsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(reparsed, j);
    }

    #[test]
    fn quick_lint_is_clean_on_builtin() {
        assert!(!quick_lint(&Manifest::builtin()).has_errors());
    }
}
