//! The clash-freedom prover: per-junction symbolic proofs over the
//! address-generation structure, the eq. 9 / Appendix B z-net rules, and
//! the closed-form FF/BP/UP pipeline interleave — no weight replay.
//!
//! # Why this is a proof for *all* cycles
//!
//! Three layers, each symbolic:
//!
//! 1. **Within a junction**, one cycle reads the `z` left memories at one
//!    address each. [`ScheduleSpec::prove_clash_free`] shows directly
//!    from the generator state that every sweep's lane→memory map `sigma`
//!    is a permutation of `0..z` (so no memory is hit twice in any cycle)
//!    and that every address column is admissible — for `Affine` sweeps
//!    the address `(phi[m] + c) mod depth` is a cyclic rotation for *any*
//!    `phi`, so each memory's address stream covers `0..depth` exactly
//!    once per sweep. This quantifies over cycles symbolically; nothing
//!    is replayed.
//! 2. **Across junctions**, `zconfig::validate` checks the structural
//!    admissibility rules: `z_i | |W_i|`, `z_i | N_{i-1}` (Appendix B
//!    memory depth), and eq. 9's right-bank rate constraint
//!    `z_{i+1} >= ceil(z_i / d_in_i)`.
//! 3. **Across the pipelined FF/BP/UP interleave**,
//!    [`Pipeline`](crate::hw::pipeline::Pipeline)'s closed-form schedule
//!    (`ff_time(i,n) = n + i`, `bp/up_time(i,n) = n + 2L - i + 1`) makes
//!    the op set at junction cycle `tau` shift-invariant once warmup
//!    completes: for `tau >= 2L + 1` every op family is active and
//!    `slots_at(tau)` is `slots_at(tau - 1)` with every batch index
//!    advanced by one, so per-cycle op uniqueness at one steady-state
//!    cycle extends to all later cycles. `audit(taus)` checks every
//!    warmup cycle plus at least one steady-state cycle (the pass clamps
//!    `taus` up to `2L + 2`), which together with shift invariance covers
//!    all `tau`. FF and UP can touch the same input activation only if
//!    the weight staleness were zero, and the closed form
//!    `staleness(i) = 2(L - i) + 1 >= 1` rules that out for every
//!    junction.
//!
//! A failed proof carries a typed counterexample: the junction, the
//! first offending cycle, and the memory bank hit twice.

use super::{Finding, Severity};
use crate::hw::context::ContextError;
use crate::hw::pipeline::Pipeline;
use crate::hw::zconfig::{self, ZConfigError};
use crate::runtime::manifest::ConfigEntry;
use crate::sparsity::clash_free::{self, ClashError, Flavor};
use crate::sparsity::config::{DoutConfig, NetConfig};
use crate::util::rng::Rng;

/// What the prover established for one config (returned only when every
/// obligation discharged).
#[derive(Clone, Debug)]
pub struct ClashProof {
    /// Junction count L.
    pub junctions: usize,
    /// Proved-clash-free parallelism per junction (the z-net the
    /// generation path would use).
    pub z: Vec<usize>,
    /// Out-degree (= sweeps per training item) per junction.
    pub sweeps: Vec<usize>,
    /// Concurrent op slots in pipeline steady state (3L - 1).
    pub steady_state_ops: usize,
    /// Junction cycles the bounded interleave audit covered (warmup plus
    /// steady state; shift invariance extends it to all cycles).
    pub audited_taus: usize,
    /// Tenant contexts the multi-tenant obligation covered (`1` = the
    /// single-tenant pipeline).
    pub contexts: usize,
    /// Proved per-context staleness `floor((2(L-i)+1)/C)` per junction
    /// (equals the Sec. III-D closed form when `contexts == 1`).
    pub context_staleness: Vec<usize>,
}

/// The out-degrees the analyzer assumes for `entry`: its `gather_dout`
/// when present, else fully connected (d_out_i = N_{i+1}).
pub fn dout_for_entry(entry: &ConfigEntry) -> DoutConfig {
    match &entry.gather_dout {
        Some(d) => DoutConfig(d.clone()),
        None => DoutConfig(entry.layers[1..].to_vec()),
    }
}

/// Map a typed schedule counterexample to a finding.
fn clash_finding(config: &str, e: ClashError) -> Finding {
    let code = match e {
        ClashError::OutOfRange { .. } => "out-of-range",
        ClashError::MemoryClash { .. } => "memory-clash",
        ClashError::NeuronRepeated { .. } => "neuron-repeated",
        ClashError::DuplicateEdge { .. } => "duplicate-edge",
    };
    let mut f = Finding::new("clash", code, Severity::Error, config, e.to_string())
        .with_junction(e.junction());
    if let Some(c) = e.cycle() {
        f = f.with_cycle(c);
    }
    if let Some(m) = e.memory() {
        f = f.with_bank(m);
    }
    f
}

/// Discharge the multi-tenant context obligation for an `l`-junction
/// pipeline against an explicit context fetch function — the general
/// form the mutation tests drive with deliberately faulted fetches
/// (alias two contexts onto one bank, drop a context's fetches).
/// Returns the typed error finding, naming the offending context via
/// the `context` coordinate, or `None` when the interleave proves out.
pub fn prove_contexts_with<F>(
    config: &str,
    l: usize,
    taus: i64,
    contexts: usize,
    fetch: F,
) -> Option<Finding>
where
    F: Fn(i64) -> Option<usize>,
{
    let pipe = Pipeline::new(l);
    match pipe.audit_contexts_with(taus, contexts, fetch) {
        Ok(()) => None,
        Err(e) => {
            let code = match e {
                ContextError::Aliased { .. } => "context-alias",
                ContextError::Skipped { .. } => "context-skip",
                ContextError::OutOfRange { .. } => "context-out-of-range",
                ContextError::StalenessLaw { .. } => "context-staleness",
            };
            let mut f = Finding::new(
                "clash",
                code,
                Severity::Error,
                config,
                format!("multi-tenant interleave violates tenant isolation: {e}"),
            );
            if let Some(c) = e.context() {
                f = f.with_context(c);
            }
            if let ContextError::StalenessLaw { junction, .. } = e {
                f = f.with_junction(junction);
            }
            Some(f)
        }
    }
}

/// Prove clash-freedom for one config end to end. `depth` overrides the
/// audited junction-cycle span (clamped up to `2L + 2` so the steady
/// state is always covered); `seed` fixes the address-generator draw —
/// the proof inspects only generator *structure* (sigma permutations,
/// rotation offsets), so a pass here holds for the schedules
/// [`crate::sparsity::generate`] materializes from any seed.
/// `contexts` sets the tenant count the multi-tenant obligation covers
/// (`1` reproves exactly the single-tenant pipeline; clamped up to 1).
pub fn prove_config(
    config: &str,
    entry: &ConfigEntry,
    depth: Option<usize>,
    seed: u64,
    contexts: usize,
) -> (Vec<Finding>, Option<ClashProof>) {
    let contexts = contexts.max(1);
    let mut out = Vec::new();
    if entry.layers.len() < 2 || entry.layers.contains(&0) {
        out.push(Finding::new(
            "clash",
            "bad-layers",
            Severity::Error,
            config,
            format!("layers {:?} do not describe a network", entry.layers),
        ));
        return (out, None);
    }
    let netc = NetConfig::new(entry.layers.clone());
    let dout = dout_for_entry(entry);
    if let Err(e) = netc.validate_dout(&dout) {
        out.push(Finding::new(
            "clash",
            "bad-dout",
            Severity::Error,
            config,
            format!("out-degrees {:?} inadmissible: {e}", dout.0),
        ));
        return (out, None);
    }

    // obligation 1: per-junction symbolic schedule proof, mirroring the
    // exact construction sparsity::generate's ClashFree path uses (same
    // default z, same flavor, one shared rng)
    let mut rng = Rng::new(seed);
    let l = netc.n_junctions();
    let mut z = Vec::with_capacity(l);
    let mut sweeps = Vec::with_capacity(l);
    for i in 0..l {
        let shape = netc.junction(i);
        let zi = clash_free::default_z(shape, dout.0[i]);
        let spec = clash_free::schedule_spec(
            shape.n_left,
            zi,
            dout.0[i],
            Flavor::Type1 { dither: false },
            &mut rng,
        );
        if let Err(e) = spec.prove_clash_free() {
            out.push(clash_finding(config, e.at_junction(i)));
        }
        z.push(zi);
        sweeps.push(dout.0[i]);
    }

    // obligation 2: z-net admissibility (eq. 9 + Appendix B)
    if let Err(e) = zconfig::validate(&netc, &dout, &z) {
        let junction = match &e {
            ZConfigError::NotDividing { junction, .. }
            | ZConfigError::DepthNotIntegral { junction, .. }
            | ZConfigError::RightBankOverrun { junction, .. } => Some(*junction),
            ZConfigError::WrongLength { .. } | ZConfigError::Unbalanced { .. } => None,
        };
        let mut f = Finding::new("clash", "zconfig", Severity::Error, config, e.to_string());
        if let Some(j) = junction {
            f = f.with_junction(j);
        }
        out.push(f);
    }

    // obligation 3: the whole-pipeline interleave — bounded audit over
    // warmup + steady state, extended to all cycles by shift invariance
    // (module docs); staleness(i) = 2(L-i)+1 >= 1 separates FF from UP
    let pipe = Pipeline::new(l);
    let audited = depth.unwrap_or(4 * l + 2).max(2 * l + 2);
    if let Err(e) = pipe.audit(audited as i64) {
        out.push(Finding::new(
            "clash",
            "pipeline-overlap",
            Severity::Error,
            config,
            format!("pipelined interleave violates per-cycle uniqueness: {e}"),
        ));
    }

    // obligation 4: the multi-tenant context interleave — round-robin
    // fetch discipline plus the per-context staleness closed form
    // floor((2(L-i)+1)/C), audited past every tenant's warmup (the span
    // scales with C so each tenant reaches steady state in the window)
    let audited_ctx = (audited * contexts + 2 * l) as i64;
    if let Some(f) = prove_contexts_with(config, l, audited_ctx, contexts, |n| {
        Some(pipe.context_of(n, contexts))
    }) {
        out.push(f);
    }

    if out.iter().any(|f| f.severity == Severity::Error) {
        return (out, None);
    }
    let proof = ClashProof {
        junctions: l,
        z: z.clone(),
        sweeps,
        steady_state_ops: pipe.steady_state_ops(),
        audited_taus: audited,
        contexts,
        context_staleness: (1..=l).map(|i| pipe.context_staleness(i, contexts)).collect(),
    };
    out.push(Finding::new(
        "clash",
        "proved",
        Severity::Info,
        config,
        format!(
            "proved clash-free for all cycles: {l} junction(s), z_net {z:?}, \
             {} concurrent steady-state ops, interleave audited over {audited} \
             cycles + shift invariance",
            proof.steady_state_ops
        ),
    ));
    if contexts > 1 {
        out.push(Finding::new(
            "clash",
            "proved-contexts",
            Severity::Info,
            config,
            format!(
                "proved {contexts}-tenant interleave isolated: round-robin context \
                 fetches audited over {audited_ctx} cycles, per-context staleness \
                 {:?} matches floor((2(L-i)+1)/C)",
                proof.context_staleness
            ),
        ));
    }
    (out, Some(proof))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;

    #[test]
    fn builtin_configs_all_prove() {
        let m = Manifest::builtin();
        for (name, entry) in &m.configs {
            let (findings, proof) = prove_config(name, entry, None, 0x1812_0116, 1);
            assert!(
                proof.is_some(),
                "{name} failed to prove: {:?}",
                findings.iter().map(|f| f.message.clone()).collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn mnist_fc4_proves_at_full_pipeline_depth() {
        let m = Manifest::builtin();
        let entry = &m.configs["mnist_fc4"];
        // L = 4: warmup ends at tau = 2L+1 = 9; audit the full first
        // steady-state window explicitly
        let (findings, proof) = prove_config("mnist_fc4", entry, Some(18), 0x1812_0116, 1);
        let proof = proof.unwrap_or_else(|| panic!("no proof: {findings:?}"));
        assert_eq!(proof.junctions, 4);
        assert_eq!(proof.steady_state_ops, 11);
        assert_eq!(proof.audited_taus, 18);
        assert_eq!(proof.z, vec![200, 25, 25, 25]);
        // single-tenant: the per-context law is the Sec. III-D closed form
        assert_eq!(proof.contexts, 1);
        assert_eq!(proof.context_staleness, vec![7, 5, 3, 1]);
    }

    #[test]
    fn multi_context_proof_reports_dilated_staleness() {
        let m = Manifest::builtin();
        let entry = &m.configs["mnist_fc4"];
        let (findings, proof) = prove_config("mnist_fc4", entry, None, 0x1812_0116, 4);
        let proof = proof.unwrap_or_else(|| panic!("no proof: {findings:?}"));
        assert_eq!(proof.contexts, 4);
        // floor([7,5,3,1] / 4): each tenant sees only its own updates
        assert_eq!(proof.context_staleness, vec![1, 1, 0, 0]);
        assert!(
            findings.iter().any(|f| f.code == "proved-contexts"),
            "multi-tenant proof must surface its own finding: {findings:?}"
        );
    }

    #[test]
    fn faulted_context_fetch_yields_typed_finding() {
        let pipe = Pipeline::new(3);
        // alias context 2 onto bank 0: the finding names context 2
        let f = prove_contexts_with("tiny", 3, 60, 4, |n| {
            let c = pipe.context_of(n, 4);
            Some(if c == 2 { 0 } else { c })
        })
        .expect("aliased fetch must fail the proof");
        assert_eq!(f.code, "context-alias");
        assert_eq!(f.severity, Severity::Error);
        assert_eq!(f.context, Some(2));
        // drop context 1's fetches: the finding names context 1
        let f = prove_contexts_with("tiny", 3, 60, 4, |n| {
            let c = pipe.context_of(n, 4);
            if c == 1 {
                None
            } else {
                Some(c)
            }
        })
        .expect("skipped fetch must fail the proof");
        assert_eq!(f.code, "context-skip");
        assert_eq!(f.context, Some(1));
        // the clean round-robin fetch proves out
        assert!(prove_contexts_with("tiny", 3, 60, 4, |n| Some(pipe.context_of(n, 4))).is_none());
    }

    #[test]
    fn degenerate_layers_are_rejected_with_typed_finding() {
        let mut entry = Manifest::builtin().configs["tiny"].clone();
        entry.layers = vec![32];
        let (findings, proof) = prove_config("tiny", &entry, None, 0, 1);
        assert!(proof.is_none());
        assert_eq!(findings[0].code, "bad-layers");
        assert_eq!(findings[0].severity, Severity::Error);
    }

    #[test]
    fn inadmissible_gather_dout_is_rejected() {
        // timit junction 0 is 39 -> 390: admissible d_out are multiples
        // of 390/gcd(39,390) = 10, so 5 gives a fractional d_in
        let mut entry = Manifest::builtin().configs["timit"].clone();
        entry.gather_dout = Some(vec![5, 9]);
        let (findings, proof) = prove_config("timit", &entry, None, 0, 1);
        assert!(proof.is_none());
        assert_eq!(findings[0].code, "bad-dout");
    }

    #[test]
    fn audit_span_is_clamped_to_cover_steady_state() {
        let m = Manifest::builtin();
        let entry = &m.configs["tiny"];
        // requesting a 1-cycle audit must not produce a vacuous proof
        let (_, proof) = prove_config("tiny", entry, Some(1), 0, 1);
        assert!(proof.unwrap().audited_taus >= 2 * 2 + 2);
    }
}
