//! Manifest lint: structural findings decidable from the manifest text
//! alone — degenerate layers/batches, inadmissible out-degrees,
//! duplicate or zero-sized tensors, program signatures that disagree
//! with the config's shapes, quant formats with no usable value range,
//! and (at the raw-document level) fields the parser would silently
//! ignore or drop.
//!
//! The error-level subset is the load-time gate:
//! [`crate::runtime::Manifest::load_or_builtin`] refuses to return a
//! manifest with error findings, and
//! [`crate::runtime::Engine::from_manifest`] asserts the same, so a
//! structurally broken config can never reach a worker thread.

use std::collections::BTreeSet;

use super::{Finding, Severity};
use crate::runtime::manifest::{ConfigEntry, Manifest, ProgramSpec};
use crate::sparsity::config::{DoutConfig, NetConfig};
use crate::util::json::Json;

/// Lint every config of a parsed manifest.
pub fn lint_manifest(manifest: &Manifest) -> Vec<Finding> {
    manifest
        .configs
        .iter()
        .flat_map(|(name, entry)| lint_entry(name, entry))
        .collect()
}

/// Lint one parsed config entry.
pub fn lint_entry(config: &str, entry: &ConfigEntry) -> Vec<Finding> {
    let mut out = Vec::new();
    if entry.layers.len() < 2 {
        out.push(Finding::new(
            "lint",
            "bad-layers",
            Severity::Error,
            config,
            format!(
                "layers {:?} do not describe a network (need >= 2 layers)",
                entry.layers
            ),
        ));
    }
    if let Some(i) = entry.layers.iter().position(|&n| n == 0) {
        out.push(Finding::new(
            "lint",
            "bad-layers",
            Severity::Error,
            config,
            format!("layer {i} has width 0"),
        ));
    }
    if entry.batch == 0 {
        out.push(Finding::new(
            "lint",
            "bad-batch",
            Severity::Error,
            config,
            "batch size 0".to_string(),
        ));
    }
    let layers_ok = !out
        .iter()
        .any(|f| f.code == "bad-layers" || f.code == "bad-batch");
    if layers_ok {
        if let Some(d) = &entry.gather_dout {
            let netc = NetConfig::new(entry.layers.clone());
            if let Err(e) = netc.validate_dout(&DoutConfig(d.clone())) {
                out.push(Finding::new(
                    "lint",
                    "bad-dout",
                    Severity::Error,
                    config,
                    format!("gather_dout {d:?} inadmissible: {e}"),
                ));
            }
        }
    }
    if let Some(q) = entry.quant {
        if q.format.max_value() < 1.0 {
            out.push(Finding::new(
                "lint",
                "quant-tiny-range",
                Severity::Warning,
                config,
                format!(
                    "{} cannot represent 1.0 (max {}): normalized inputs clip at ingest",
                    q.format,
                    q.format.max_value()
                ),
            ));
        }
    }
    if let Some(act) = &entry.act {
        out.extend(lint_act(config, entry, act, layers_ok));
    }
    for (tag, program) in &entry.programs {
        out.extend(lint_program(config, entry, tag, program, layers_ok));
    }
    out
}

/// Lint an activation-sparsity spec against the config's hidden-layer
/// widths. A spec that selects nothing is an error (the network would
/// emit constant logits); a spec that can never drop a neuron is a
/// warning (pure overhead, weight-sparse-only in disguise).
fn lint_act(
    config: &str,
    entry: &ConfigEntry,
    act: &crate::nn::actsparse::ActSpec,
    layers_ok: bool,
) -> Vec<Finding> {
    use crate::nn::actsparse::ActMode;
    let mut out = Vec::new();
    match act.mode {
        ActMode::TopK(0) => {
            out.push(Finding::new(
                "lint",
                "bad-act",
                Severity::Error,
                config,
                "act_sparsity topk k=0 zeroes every hidden activation".to_string(),
            ));
        }
        ActMode::TopK(k) => {
            // hidden layers are layers[1..len-1]; the input layer and the
            // logits are never masked
            if layers_ok && entry.layers.len() > 2 {
                let hidden = &entry.layers[1..entry.layers.len() - 1];
                if hidden.iter().all(|&n| k >= n) {
                    out.push(Finding::new(
                        "lint",
                        "act-degenerate",
                        Severity::Warning,
                        config,
                        format!(
                            "act_sparsity topk k={k} >= every hidden width {hidden:?}: \
                             the mask is always all-ones (weight-sparse-only plus \
                             selection overhead)"
                        ),
                    ));
                }
            }
        }
        ActMode::Threshold(t) => {
            if !t.is_finite() || t < 0.0 {
                out.push(Finding::new(
                    "lint",
                    "bad-act",
                    Severity::Error,
                    config,
                    format!("act_sparsity threshold {t} must be finite and >= 0"),
                ));
            }
        }
    }
    out.push(Finding::new(
        "lint",
        "act-spec",
        Severity::Info,
        config,
        format!("activation sparsity enabled: {act} on hidden layers"),
    ));
    out
}

/// Lint one program signature: duplicate tensor names per side, zero
/// dimensions, and — for the conventional program tags — agreement of
/// the `x` / `logits` / `y` shapes with the config's layers and batch.
fn lint_program(
    config: &str,
    entry: &ConfigEntry,
    tag: &str,
    program: &ProgramSpec,
    layers_ok: bool,
) -> Vec<Finding> {
    let mut out = Vec::new();
    for (side, specs) in [("input", &program.inputs), ("output", &program.outputs)] {
        let mut seen = BTreeSet::new();
        for t in specs {
            if !seen.insert(t.name.as_str()) {
                out.push(Finding::new(
                    "lint",
                    "dup-tensor",
                    Severity::Error,
                    config,
                    format!("program '{tag}': duplicate {side} tensor '{}'", t.name),
                ));
            }
            if t.shape.contains(&0) {
                out.push(Finding::new(
                    "lint",
                    "zero-dim",
                    Severity::Error,
                    config,
                    format!(
                        "program '{tag}': {side} tensor '{}' has a zero dimension {:?}",
                        t.name, t.shape
                    ),
                ));
            }
        }
    }
    if !layers_ok {
        return out;
    }
    let batch = entry.batch;
    let n0 = entry.layers[0];
    let classes = *entry.layers.last().unwrap();
    let mut expect = |side: &str, name: &str, want: Vec<usize>| {
        let specs = if side == "input" {
            &program.inputs
        } else {
            &program.outputs
        };
        if let Some(t) = specs.iter().find(|t| t.name == name) {
            if t.shape != want {
                out.push(Finding::new(
                    "lint",
                    "shape-mismatch",
                    Severity::Error,
                    config,
                    format!(
                        "program '{tag}': {side} '{name}' has shape {:?}, config \
                         implies {want:?}",
                        t.shape
                    ),
                ));
            }
        }
    };
    match tag {
        "forward" | "forward_quantized" | "gather_forward" => {
            expect("input", "x", vec![batch, n0]);
            expect("output", "logits", vec![batch, classes]);
        }
        "train" => {
            expect("input", "x", vec![batch, n0]);
            expect("input", "y", vec![batch]);
        }
        _ => {}
    }
    out
}

/// Keys [`Manifest::parse`] reads from a config object.
const CONFIG_KEYS: &[&str] = &[
    "layers",
    "batch",
    "gather_dout",
    "quant",
    "act_sparsity",
    "programs",
];
/// Keys the parser reads from a program object.
const PROGRAM_KEYS: &[&str] = &["file", "inputs", "outputs"];
/// Keys the parser reads from a tensor-spec object.
const SPEC_KEYS: &[&str] = &["name", "shape", "dtype"];

/// Lint the raw manifest document for problems the parser cannot report:
/// unknown fields it silently ignores, and `gather_dout` entries it
/// silently drops (which would shorten the out-degree list without any
/// error). Call with text that already parsed via [`Manifest::parse`].
pub fn lint_text(text: &str) -> Vec<Finding> {
    match Json::parse(text) {
        Ok(doc) => lint_json(&doc),
        Err(e) => vec![Finding::new(
            "lint",
            "parse-error",
            Severity::Error,
            "<manifest>",
            format!("manifest is not valid JSON: {e}"),
        )],
    }
}

/// [`lint_text`] over an already-parsed document.
pub fn lint_json(doc: &Json) -> Vec<Finding> {
    let mut out = Vec::new();
    let Some(root) = doc.as_obj() else {
        out.push(Finding::new(
            "lint",
            "bad-manifest",
            Severity::Error,
            "<manifest>",
            "manifest root is not an object".to_string(),
        ));
        return out;
    };
    for key in root.keys() {
        if key != "configs" {
            out.push(unknown_field("<manifest>", "manifest", key));
        }
    }
    let Some(configs) = root.get("configs").and_then(Json::as_obj) else {
        out.push(Finding::new(
            "lint",
            "bad-manifest",
            Severity::Error,
            "<manifest>",
            "manifest has no 'configs' object".to_string(),
        ));
        return out;
    };
    for (name, entry) in configs {
        let Some(eo) = entry.as_obj() else {
            out.push(Finding::new(
                "lint",
                "bad-manifest",
                Severity::Error,
                name,
                "config is not an object".to_string(),
            ));
            continue;
        };
        for key in eo.keys() {
            if !CONFIG_KEYS.contains(&key.as_str()) {
                out.push(unknown_field(name, "config", key));
            }
        }
        if let Some(gd) = entry.get("gather_dout").and_then(Json::as_arr) {
            for (i, v) in gd.iter().enumerate() {
                if v.as_usize().is_none() {
                    out.push(Finding::new(
                        "lint",
                        "bad-dout-entry",
                        Severity::Error,
                        name,
                        format!(
                            "gather_dout[{i}] = {v} is not a non-negative integer \
                             (the parser silently drops it, shortening the \
                             out-degree list)"
                        ),
                    ));
                }
            }
        }
        let Some(programs) = entry.get("programs").and_then(Json::as_obj) else {
            continue;
        };
        for (tag, program) in programs {
            let Some(po) = program.as_obj() else { continue };
            for key in po.keys() {
                if !PROGRAM_KEYS.contains(&key.as_str()) {
                    out.push(unknown_field(name, &format!("program '{tag}'"), key));
                }
            }
            for side in ["inputs", "outputs"] {
                let Some(specs) = program.get(side).and_then(Json::as_arr) else {
                    continue;
                };
                for t in specs {
                    let Some(to) = t.as_obj() else { continue };
                    for key in to.keys() {
                        if !SPEC_KEYS.contains(&key.as_str()) {
                            out.push(unknown_field(
                                name,
                                &format!("program '{tag}' tensor"),
                                key,
                            ));
                        }
                    }
                }
            }
        }
    }
    out
}

fn unknown_field(config: &str, scope: &str, key: &str) -> Finding {
    Finding::new(
        "lint",
        "unknown-field",
        Severity::Warning,
        config,
        format!("unknown {scope} field '{key}' (silently ignored by the parser)"),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::fixed::QFormat;
    use crate::runtime::manifest::QuantSpec;

    #[test]
    fn builtin_lints_clean() {
        assert!(lint_manifest(&Manifest::builtin())
            .iter()
            .all(|f| f.severity != Severity::Error));
    }

    #[test]
    fn degenerate_entries_are_errors() {
        let mut entry = Manifest::builtin().configs["tiny"].clone();
        entry.layers = vec![32, 0, 8];
        entry.batch = 0;
        let findings = lint_entry("tiny", &entry);
        assert!(findings.iter().any(|f| f.code == "bad-layers"));
        assert!(findings.iter().any(|f| f.code == "bad-batch"));
    }

    #[test]
    fn inadmissible_gather_dout_is_an_error() {
        // timit junction 0 is 39 -> 390: admissible d_out are multiples
        // of 390/gcd(39,390) = 10, so 5 gives a fractional d_in
        let mut entry = Manifest::builtin().configs["timit"].clone();
        entry.gather_dout = Some(vec![5, 9]);
        assert!(lint_entry("timit", &entry)
            .iter()
            .any(|f| f.code == "bad-dout" && f.severity == Severity::Error));
    }

    #[test]
    fn duplicate_and_mismatched_tensors_are_errors() {
        let mut entry = Manifest::builtin().configs["tiny"].clone();
        {
            let fwd = entry.programs.get_mut("forward").unwrap();
            let x = fwd.inputs.last().unwrap().clone();
            fwd.inputs.push(x); // duplicate 'x'
            fwd.outputs[0].shape = vec![16, 99]; // logits disagree with layers
        }
        let findings = lint_entry("tiny", &entry);
        assert!(findings.iter().any(|f| f.code == "dup-tensor"));
        assert!(findings.iter().any(|f| f.code == "shape-mismatch"));
    }

    #[test]
    fn tiny_quant_range_is_a_warning() {
        let mut entry = Manifest::builtin().configs["tiny"].clone();
        entry.quant = Some(QuantSpec {
            format: QFormat::new(0, 4),
        });
        // Q0.4 max value is 15/16 < 1.0
        assert!(lint_entry("tiny", &entry)
            .iter()
            .any(|f| f.code == "quant-tiny-range" && f.severity == Severity::Warning));
    }

    #[test]
    fn act_spec_lint_findings() {
        use crate::nn::actsparse::{ActMode, ActSpec};
        // no spec -> no act findings at all (default report shape is pinned
        // by tests/analyzer_mutations.rs)
        let entry = Manifest::builtin().configs["tiny"].clone();
        assert!(lint_entry("tiny", &entry)
            .iter()
            .all(|f| !f.code.starts_with("act") && f.code != "bad-act"));

        // k=0 zeroes the network: error
        let e = entry.clone().with_act(ActSpec::top_k(0));
        assert!(lint_entry("tiny", &e)
            .iter()
            .any(|f| f.code == "bad-act" && f.severity == Severity::Error));

        // k >= every hidden width: degenerate all-ones mask, warning
        let e = entry.clone().with_act(ActSpec::top_k(10_000));
        let fs = lint_entry("tiny", &e);
        assert!(fs
            .iter()
            .any(|f| f.code == "act-degenerate" && f.severity == Severity::Warning));
        assert!(fs
            .iter()
            .any(|f| f.code == "act-spec" && f.severity == Severity::Info));

        // a sane spec lints clean apart from the info line
        let e = entry.clone().with_act(ActSpec::top_k(4));
        assert!(lint_entry("tiny", &e)
            .iter()
            .all(|f| f.severity != Severity::Error));

        // non-finite threshold (unreachable via the parser, reachable via
        // the builder) is an error
        let e = entry.with_act(ActSpec {
            mode: ActMode::Threshold(f32::NAN),
        });
        assert!(lint_entry("tiny", &e)
            .iter()
            .any(|f| f.code == "bad-act" && f.severity == Severity::Error));
    }

    #[test]
    fn act_sparsity_is_a_known_manifest_key() {
        let text = r#"{"configs": {"tiny": {
            "layers": [32, 16, 8], "batch": 16,
            "act_sparsity": {"mode": "topk", "k": 4},
            "programs": {}}}}"#;
        assert!(lint_text(text)
            .iter()
            .all(|f| f.code != "unknown-field"));
    }

    #[test]
    fn raw_document_lint_catches_silent_drops() {
        let text = r#"{"configs": {"tiny": {
            "layers": [32, 16, 8], "batch": 16, "layrs": true,
            "gather_dout": [4, -1],
            "programs": {"train": {"file": "t.hlo", "inputz": []}}}}}"#;
        let findings = lint_text(text);
        assert!(
            findings
                .iter()
                .filter(|f| f.code == "unknown-field")
                .count()
                >= 2,
            "{findings:?}"
        );
        assert!(findings
            .iter()
            .any(|f| f.code == "bad-dout-entry" && f.severity == Severity::Error));
    }

    #[test]
    fn non_object_root_is_an_error() {
        assert!(lint_text("[1,2]")
            .iter()
            .any(|f| f.code == "bad-manifest"));
        assert!(lint_text("{nope")
            .iter()
            .any(|f| f.code == "parse-error"));
    }
}
