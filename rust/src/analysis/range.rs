//! Quantization range analysis: interval propagation through the Qm.n
//! dataflow, bounding every activation and wide MAC accumulator without
//! executing the network.
//!
//! # Derivation
//!
//! The quantized forward pass (`nn::fixed`) computes, per right neuron,
//! `y = clamp(shift_round(sum_e wq[e] * a[idx[e]] + (bq << n), n))` with
//! ReLU on non-terminal junctions. Every step is monotone in its
//! operands, so interval bounds compose exactly:
//!
//! - activations start in the quantized input interval `[-b, b]`;
//! - each edge product `wq * a` lies in `[wq*lo, wq*hi]` (or the swap
//!   for negative weights), and the accumulator interval is the sum of
//!   edge intervals plus the bias at scale `2^(2n)` — computed in i128
//!   so the analysis itself cannot overflow, which also lets it detect
//!   when the runtime's *i64* accumulator could;
//! - `shift_round` (round half up) is monotone nondecreasing, so the
//!   post-rounding interval is its image of the accumulator endpoints;
//! - saturation is reachable iff that interval escapes
//!   `[min_raw, max_raw]`; the clamped (and, on hidden junctions,
//!   rectified) interval seeds the next junction.
//!
//! Soundness: by induction every concrete activation lies inside its
//! interval, so "interval never escapes the raw range" proves no input
//! in `[-b, b]` can saturate — the premise of the `forward_error_bound`
//! certificate. The analysis is conservative (a flagged interval may be
//! jointly unreachable, since per-neuron worst cases need different
//! inputs) but never optimistic.
//!
//! # Certified range vs. asserted range
//!
//! Widening `b` widens every derived interval (each step preserves
//! interval inclusion), so soundness is *monotone* in `b` and
//! [`certified_raw_bound`] can binary-search the largest provably safe
//! input magnitude. The analyzer's default mode reports that certified
//! range; it errors only when *no* safe range exists (or parameters
//! clip outright) — for wide He-initialized junctions the worst-case
//! bound grows multiplicatively per layer, so demanding safety at the
//! full representable input range would reject formats that are
//! perfectly safe at the data's actual scale. Passing an explicit
//! input range turns "no saturation reachable at that range" into a
//! hard proof obligation, with the first breaking junction and the
//! minimal fixing Qm.n reported on failure.

use super::{Finding, Severity};
use crate::nn::fixed::{FixedSparseNet, QFormat};
use crate::nn::sparse::SparseNet;
use crate::runtime::manifest::ConfigEntry;
use crate::sparsity::config::NetConfig;
use crate::sparsity::{generate, Method};
use crate::util::rng::Rng;

/// Interval bounds derived for one junction.
#[derive(Clone, Copy, Debug)]
pub struct LayerBounds {
    /// Junction index.
    pub junction: usize,
    /// Lower bound of the wide MAC accumulator (bias included, scale
    /// `2^(2n)`), over all right neurons.
    pub acc_lo: i128,
    /// Upper accumulator bound.
    pub acc_hi: i128,
    /// Lower bound of the post-rounding, pre-clamp output (raw scale).
    pub out_lo: i128,
    /// Upper post-rounding bound.
    pub out_hi: i128,
    /// True when the output interval escapes `[min_raw, max_raw]`.
    pub saturable: bool,
}

/// Outcome of propagating one input interval through the whole net.
#[derive(Clone, Debug)]
pub struct RangeCheck {
    /// Per-junction bounds, input to logits.
    pub layers: Vec<LayerBounds>,
    /// First junction whose output interval can saturate, if any.
    pub first_saturable: Option<usize>,
    /// First junction whose accumulator bound exceeds the runtime's i64
    /// accumulator, if any (wraparound would be undetected at runtime).
    pub acc_overflow: Option<usize>,
}

impl RangeCheck {
    /// True when neither saturation nor accumulator overflow is
    /// reachable.
    pub fn sound(&self) -> bool {
        self.first_saturable.is_none() && self.acc_overflow.is_none()
    }
}

/// i128 twin of `nn::fixed`'s round-half-up rounding shift; a unit test
/// in `nn::fixed` pins the two to identical results on the shared i64
/// domain.
pub(crate) fn shift_round_wide(v: i128, n: u32) -> i128 {
    if n == 0 {
        v
    } else {
        (v + (1i128 << (n - 1))) >> n
    }
}

/// Propagate the raw input interval `[in_lo, in_hi]` (every input neuron)
/// through `qnet`, returning per-junction bounds.
pub fn propagate(qnet: &FixedSparseNet, in_lo: i32, in_hi: i32) -> RangeCheck {
    assert!(in_lo <= in_hi, "empty input interval");
    let fmt = qnet.fmt;
    let n = fmt.frac_bits;
    let (min_raw, max_raw) = (fmt.min_raw() as i128, fmt.max_raw() as i128);
    let mut lo = vec![in_lo as i128; qnet.layers[0]];
    let mut hi = vec![in_hi as i128; qnet.layers[0]];
    let mut layers = Vec::with_capacity(qnet.junctions.len());
    let mut first_saturable = None;
    let mut acc_overflow = None;
    let last = qnet.junctions.len() - 1;
    for (ji, j) in qnet.junctions.iter().enumerate() {
        let mut next_lo = vec![0i128; j.n_right];
        let mut next_hi = vec![0i128; j.n_right];
        let mut bounds = LayerBounds {
            junction: ji,
            acc_lo: i128::MAX,
            acc_hi: i128::MIN,
            out_lo: i128::MAX,
            out_hi: i128::MIN,
            saturable: false,
        };
        for r in 0..j.n_right {
            let mut acc_lo = 0i128;
            let mut acc_hi = 0i128;
            for e in j.offsets[r] as usize..j.offsets[r + 1] as usize {
                let w = j.wq[e] as i128;
                let li = j.idx[e] as usize;
                if w >= 0 {
                    acc_lo += w * lo[li];
                    acc_hi += w * hi[li];
                } else {
                    acc_lo += w * hi[li];
                    acc_hi += w * lo[li];
                }
            }
            // fold_mac adds the bias at scale 2^(2n) before the single
            // rounding shift
            let b = (j.bq[r] as i128) << n;
            acc_lo += b;
            acc_hi += b;
            let wide = acc_lo < i64::MIN as i128 || acc_hi > i64::MAX as i128;
            if wide && acc_overflow.is_none() {
                acc_overflow = Some(ji);
            }
            let out_lo = shift_round_wide(acc_lo, n);
            let out_hi = shift_round_wide(acc_hi, n);
            if out_lo < min_raw || out_hi > max_raw {
                bounds.saturable = true;
            }
            bounds.acc_lo = bounds.acc_lo.min(acc_lo);
            bounds.acc_hi = bounds.acc_hi.max(acc_hi);
            bounds.out_lo = bounds.out_lo.min(out_lo);
            bounds.out_hi = bounds.out_hi.max(out_hi);
            // the hardware clamps, then rectifies on hidden junctions
            let mut c_lo = out_lo.clamp(min_raw, max_raw);
            let mut c_hi = out_hi.clamp(min_raw, max_raw);
            if ji != last {
                c_lo = c_lo.max(0);
                c_hi = c_hi.max(0);
            }
            next_lo[r] = c_lo;
            next_hi[r] = c_hi;
        }
        if bounds.saturable && first_saturable.is_none() {
            first_saturable = Some(ji);
        }
        layers.push(bounds);
        lo = next_lo;
        hi = next_hi;
    }
    RangeCheck {
        layers,
        first_saturable,
        acc_overflow,
    }
}

/// The largest raw input magnitude `b` such that inputs in `[-b, b]`
/// provably cannot saturate or overflow (`None` when even `b = 0` is
/// unsafe — the parameters alone saturate the format). Binary search is
/// valid because soundness is monotone in `b` (module docs).
pub fn certified_raw_bound(qnet: &FixedSparseNet) -> Option<i32> {
    let sound_at = |b: i32| propagate(qnet, -b, b).sound();
    if !sound_at(0) {
        return None;
    }
    let mut lo = 0i32; // sound
    let mut hi = qnet.fmt.max_raw(); // unknown
    if sound_at(hi) {
        return Some(hi);
    }
    while hi - lo > 1 {
        let mid = lo + (hi - lo) / 2;
        if sound_at(mid) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    Some(lo)
}

/// The largest f32 input magnitude that quantizes within `[-b, b]`
/// (defensively nudged down so `fmt.quantize` of the returned value
/// never exceeds `b` despite f32/f64 rounding).
pub fn value_bound(fmt: QFormat, b: i32) -> f32 {
    let mut r = (b as f64 / fmt.scale()) as f32;
    while r > 0.0 && fmt.quantize(r) > b {
        r = f32::from_bits(r.to_bits() - 1);
    }
    r.max(0.0)
}

/// What the range analysis certified for one concrete quantized net.
#[derive(Clone, Debug)]
pub struct RangeCertificate {
    /// Format analyzed.
    pub fmt: QFormat,
    /// Raw magnitude of the explicitly requested input range, when one
    /// was asserted (clamped into the representable range).
    pub requested_raw: Option<i32>,
    /// Largest provably safe raw input magnitude (`None`: no safe
    /// range exists).
    pub certified_raw: Option<i32>,
    /// [`value_bound`] of `certified_raw`.
    pub certified_value: Option<f32>,
    /// The propagation backing the verdict: at the requested range when
    /// one was asserted, else at the certified range (or `[0, 0]` when
    /// none exists).
    pub check: RangeCheck,
}

/// Analyze one concrete quantized net, emitting findings plus the
/// certificate. With `input_range = None` (certify mode) the pass
/// errors only on clipped parameters or a format with *no* safe input
/// range, and reports the certified maximal range; with `Some(r)` it
/// additionally *proves* no saturation is reachable for `|x| <= r` or
/// errors with the first breaking junction. This is the entry point for
/// actual served weights (`serve --quant` runs it on the net it is
/// about to serve); [`analyze_entry`] wraps it for the seeded parameter
/// draw a config describes.
pub fn analyze_qnet(
    config: &str,
    qnet: &FixedSparseNet,
    input_range: Option<f32>,
) -> (Vec<Finding>, RangeCertificate) {
    let fmt = qnet.fmt;
    let mut out = Vec::new();
    let clipped = qnet.clipped_params();
    if clipped > 0 {
        let total =
            qnet.n_edges() + qnet.junctions.iter().map(|j| j.bq.len()).sum::<usize>();
        out.push(Finding::new(
            "range",
            "param-clip",
            Severity::Error,
            config,
            format!(
                "{fmt} cannot represent the parameter range: {clipped} of {total} \
                 parameters clipped at quantization (the forward error bound's \
                 |dw| <= ulp/2 premise is void)"
            ),
        ));
    }

    let certified_raw = certified_raw_bound(qnet);
    let certified_value = certified_raw.map(|b| value_bound(fmt, b));
    match (certified_raw, certified_value) {
        (Some(b), Some(v)) => out.push(Finding::new(
            "range",
            "certified-range",
            Severity::Info,
            config,
            format!(
                "certified input range: no activation or MAC output of the \
                 {} junction(s) can saturate {fmt} for |x| <= {v} (raw |x_q| <= {b})",
                qnet.junctions.len()
            ),
        )),
        _ => {
            let probe = propagate(qnet, 0, 0);
            let mut f = Finding::new(
                "range",
                "no-safe-range",
                Severity::Error,
                config,
                format!("no input range is saturation-free: parameters alone saturate {fmt}"),
            );
            if let Some(ji) = probe.first_saturable.or(probe.acc_overflow) {
                f = f.with_junction(ji);
            }
            out.push(f);
        }
    }

    let mut requested_raw = None;
    if let Some(r) = input_range {
        let want = (r.abs() as f64 * fmt.scale()).round();
        let req = if want > fmt.max_raw() as f64 {
            out.push(Finding::new(
                "range",
                "input-clip",
                Severity::Warning,
                config,
                format!(
                    "inputs at |x| <= {} clip at the {fmt} range (max {}); \
                     analysis proceeds at the clamped bound",
                    r.abs(),
                    fmt.max_value()
                ),
            ));
            fmt.max_raw()
        } else {
            want as i32
        };
        requested_raw = Some(req);
        let check = propagate(qnet, -req, req);
        if let Some(ji) = check.acc_overflow {
            out.push(
                Finding::new(
                    "range",
                    "acc-overflow",
                    Severity::Error,
                    config,
                    format!(
                        "junction {ji}: wide MAC accumulator bound exceeds the \
                         runtime's i64 accumulator for inputs |x| <= {} — \
                         wraparound reachable",
                        r.abs()
                    ),
                )
                .with_junction(ji),
            );
        }
        if let Some(ji) = check.first_saturable {
            let lb = &check.layers[ji];
            out.push(
                Finding::new(
                    "range",
                    "saturation",
                    Severity::Error,
                    config,
                    format!(
                        "junction {ji}: output interval [{}, {}] escapes the {fmt} \
                         raw range [{}, {}] for inputs |x| <= {} — saturation \
                         reachable",
                        lb.out_lo,
                        lb.out_hi,
                        fmt.min_raw(),
                        fmt.max_raw(),
                        r.abs()
                    ),
                )
                .with_junction(ji),
            );
        } else if check.acc_overflow.is_none() && clipped == 0 {
            out.push(Finding::new(
                "range",
                "no-saturation",
                Severity::Info,
                config,
                format!(
                    "proved: no activation or MAC output saturates {fmt} for inputs \
                     |x| <= {} ({} junctions, {} edges)",
                    r.abs(),
                    qnet.junctions.len(),
                    qnet.n_edges()
                ),
            ));
        }
        let cert = RangeCertificate {
            fmt,
            requested_raw,
            certified_raw,
            certified_value,
            check,
        };
        return (out, cert);
    }

    let fallback = certified_raw.unwrap_or(0);
    let cert = RangeCertificate {
        fmt,
        requested_raw,
        certified_raw,
        certified_value,
        check: propagate(qnet, -fallback, fallback),
    };
    (out, cert)
}

/// Smallest `Qm.n` (same `n`, minimal `m`) under which `snet` quantizes
/// with zero clipped parameters and the propagation at `input_range` is
/// sound. `None` when no representable format works.
pub fn suggest_format(snet: &SparseNet, frac_bits: u32, input_range: f32) -> Option<QFormat> {
    for int_bits in 1..=31u32.saturating_sub(frac_bits) {
        let fmt = QFormat::new_checked(int_bits, frac_bits)?;
        let qnet = FixedSparseNet::from_f32(snet, fmt);
        if qnet.clipped_params() > 0 {
            continue;
        }
        let b = (input_range.abs() as f64 * fmt.scale()).round();
        if b > fmt.max_raw() as f64 {
            continue;
        }
        if propagate(&qnet, -(b as i32), b as i32).sound() {
            return Some(fmt);
        }
    }
    None
}

/// Config-level wrapper: draw the pattern and He-initialized parameters
/// the runtime would construct (seeded — the same construction the
/// repo's quantized demos serve), quantize at the config's (or the
/// override) format, and run [`analyze_qnet`]. The certificate applies
/// to the analyzed parameter draw; trained weights are re-certified at
/// serve time via [`analyze_qnet`] on the actual net.
pub fn analyze_entry(
    config: &str,
    entry: &ConfigEntry,
    quant: Option<QFormat>,
    input_range: Option<f32>,
    seed: u64,
) -> Vec<Finding> {
    let Some(fmt) = quant.or(entry.quant.map(|q| q.format)) else {
        return vec![Finding::new(
            "range",
            "skipped",
            Severity::Info,
            config,
            "no quant spec: range analysis skipped (pass --quant Qm.n to force)".to_string(),
        )];
    };
    // structural prerequisites are the clash pass's findings; don't
    // duplicate them here
    if entry.layers.len() < 2 || entry.layers.contains(&0) {
        return Vec::new();
    }
    let netc = NetConfig::new(entry.layers.clone());
    let dout = super::clash::dout_for_entry(entry);
    if netc.validate_dout(&dout).is_err() {
        return Vec::new();
    }
    let mut rng = Rng::new(seed);
    let pattern = generate(Method::ClashFree, &netc, &dout, None, &mut rng);
    let snet = SparseNet::init_he(&pattern, 0.1, &mut rng);
    let qnet = FixedSparseNet::from_f32(&snet, fmt);
    let (mut out, _cert) = analyze_qnet(config, &qnet, input_range);
    if out.iter().any(|f| f.severity == Severity::Error) {
        if let Some(r) = input_range {
            if let Some(fix) = suggest_format(&snet, fmt.frac_bits, r) {
                if fix != fmt {
                    out.push(Finding::new(
                        "range",
                        "suggest-format",
                        Severity::Warning,
                        config,
                        format!(
                            "minimal saturation-free format at n={}: {fix}",
                            fmt.frac_bits
                        ),
                    ));
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::fixed::FixedSparseLayer;
    use crate::nn::sparse::SparseLayer;
    use crate::sparsity::config::DoutConfig;

    fn tiny_qnet(fmt: QFormat, seed: u64) -> FixedSparseNet {
        let netc = NetConfig::new(vec![32, 16, 8]);
        let dout = DoutConfig(vec![4, 4]);
        let mut rng = Rng::new(seed);
        let pattern = generate(Method::ClashFree, &netc, &dout, None, &mut rng);
        let snet = SparseNet::init_he(&pattern, 0.1, &mut rng);
        FixedSparseNet::from_f32(&snet, fmt)
    }

    /// 2 -> 1 net with both weights at `w`, bias `b` (deterministic
    /// saturation fixtures).
    fn micro_net(w: f32, b: f32) -> SparseNet {
        SparseNet {
            layers: vec![2, 1],
            junctions: vec![SparseLayer {
                n_left: 2,
                n_right: 1,
                offsets: vec![0, 2],
                idx: vec![0, 1],
                wc: vec![w, w],
                bias: vec![b],
            }],
        }
    }

    #[test]
    fn default_format_certifies_a_nonempty_range_on_tiny() {
        let qnet = tiny_qnet(QFormat::default(), 7);
        let (findings, cert) = analyze_qnet("tiny", &qnet, None);
        assert!(
            findings.iter().all(|f| f.severity != Severity::Error),
            "{findings:?}"
        );
        assert!(cert.certified_raw.unwrap() > 0);
        assert!(cert.check.sound());
        assert!(findings.iter().any(|f| f.code == "certified-range"));
    }

    #[test]
    fn saturating_fixture_is_rejected_at_asserted_range() {
        // Q2.4: max_raw = 63. Both weights quantize to 3.75 * 16 = 60;
        // inputs at |x| <= 1 give acc_hi = 2 * 60 * 16 = 1920, out 120 > 63.
        let fmt = QFormat::new(2, 4);
        let qnet = FixedSparseNet::from_f32(&micro_net(3.75, 0.0), fmt);
        assert_eq!(qnet.clipped_params(), 0);
        let (findings, cert) = analyze_qnet("micro", &qnet, Some(1.0));
        let sat = findings
            .iter()
            .find(|f| f.code == "saturation")
            .expect("must flag saturation");
        assert_eq!(sat.severity, Severity::Error);
        assert_eq!(sat.junction, Some(0));
        // ... but a smaller input range is still certified
        let b = cert.certified_raw.unwrap();
        assert!(b < cert.requested_raw.unwrap());
        assert!(propagate(&qnet, -b, b).sound());
    }

    #[test]
    fn clipping_parameters_are_an_error() {
        // Q1.4 max_value = 1.9375 < 3.75: both weights clip
        let qnet = FixedSparseNet::from_f32(&micro_net(3.75, 0.0), QFormat::new(1, 4));
        let (findings, _) = analyze_qnet("micro", &qnet, None);
        assert!(findings.iter().any(|f| f.code == "param-clip"
            && f.severity == Severity::Error));
    }

    #[test]
    fn saturating_bias_means_no_safe_range() {
        // bias alone exceeds the raw range: raw bias would be
        // 3.9 * 16 = 62 on Q2.4 (fits), but a *hand-set* raw weight
        // layer lets us pin bias-only saturation exactly
        let fmt = QFormat::new(2, 4);
        let junction = FixedSparseLayer {
            n_left: 1,
            n_right: 2,
            offsets: vec![0, 1, 2],
            idx: vec![0, 0],
            wq: vec![0, 0],
            // two biases at scale 2^4 whose sum-free fold already
            // escapes: 70 > max_raw = 63
            bq: vec![70, 0],
            clipped: 0,
            fmt,
        };
        let qnet = FixedSparseNet {
            layers: vec![1, 2],
            junctions: vec![junction],
            fmt,
        };
        let (findings, cert) = analyze_qnet("micro", &qnet, None);
        assert!(cert.certified_raw.is_none());
        let f = findings.iter().find(|f| f.code == "no-safe-range").unwrap();
        assert_eq!(f.severity, Severity::Error);
        assert_eq!(f.junction, Some(0));
    }

    #[test]
    fn certified_bound_is_maximal() {
        let qnet = tiny_qnet(QFormat::default(), 11);
        let b = certified_raw_bound(&qnet).unwrap();
        assert!(propagate(&qnet, -b, b).sound());
        if b < qnet.fmt.max_raw() {
            assert!(!propagate(&qnet, -(b + 1), b + 1).sound());
        }
        let v = value_bound(qnet.fmt, b);
        assert!(qnet.fmt.quantize(v) <= b);
    }

    #[test]
    fn suggest_format_finds_the_minimal_sound_widening() {
        // weights 3.0: Q1.3 clips (max 1.875); Q2.3 holds them (24 raw)
        // but saturates at |x| <= 1 (out 48 > 31); Q3.3 is the first
        // sound format (48 <= 63)
        let snet = micro_net(3.0, 0.0);
        assert_eq!(suggest_format(&snet, 3, 1.0), Some(QFormat::new(3, 3)));
    }

    #[test]
    fn shift_round_wide_matches_formula() {
        assert_eq!(shift_round_wide(0, 10), 0);
        assert_eq!(shift_round_wide(1 << 9, 10), 1); // half rounds up
        assert_eq!(shift_round_wide((1 << 9) - 1, 10), 0);
        assert_eq!(shift_round_wide(-(1 << 9), 10), 0); // half rounds toward +inf
        assert_eq!(shift_round_wide(5, 0), 5);
    }
}
