//! Adaptive micro-batching between the socket front-end and the engine.
//!
//! A TCP edge degenerates into batch-1 engine calls if every connection
//! handler submits its requests one at a time: the engine pays one full
//! fixed-batch execution per request, exactly the failure mode the
//! paper's hardware avoids by keeping its junction pipeline full. The
//! [`MicroBatcher`] closes that gap: connection handlers *enqueue*
//! requests (never blocking on the engine), a collector thread coalesces
//! everything that arrives within one *batch window* into a group, and
//! flushes the whole group into the [`Client`]'s worker shards
//! back-to-back — so the service's dynamic batcher sees the group
//! together and executes it as one (or few) engine batches.
//!
//! Flush policy — whichever comes first:
//! - **full**: the group reaches the model's engine batch size (waiting
//!   longer could not make the engine batch any fuller), or
//! - **deadline**: [`BatcherConfig::window`] has elapsed since the
//!   group's *first* request arrived (bounding the latency a lone
//!   request can pay; the window is armed per group, not a fixed tick,
//!   so an idle service adds no latency jitter).
//!
//! The window is the deadline knob exposed on the CLI
//! (`serve --listen ... --batch-window USEC`): 0 flushes every request
//! immediately (pure pass-through, lowest latency), larger values trade
//! queueing latency for fuller engine batches. Achieved coalescing is
//! observable: [`BatcherMetrics`] counts flushes and coalesced requests
//! (their ratio is the achieved mean coalesced batch size reported in
//! `BENCH_serve.json`'s `net` section), split by flush cause.
//!
//! Completion is pipelined: the collector hands each flushed group (a
//! vector of [`PendingPrediction`]s) to a completion thread and
//! immediately resumes collecting, so waiting on one group's engine
//! execution never blocks coalescing of the next.
//!
//! Failure isolation: every responder runs under `catch_unwind`, so a
//! panicking delivery callback (one broken connection's closure) loses
//! only its own response — counted in
//! [`BatcherMetrics::responder_panics`] — instead of killing the
//! completion thread and, through a poisoned lock, every other
//! connection. All internal locks use the poison-recovering guards
//! from [`crate::util::sync`] for the same reason.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::{Client, PendingPrediction, Prediction, ServeError};
use crate::obs::registry::{Registry, Sample};
use crate::obs::trace::ReqTrace;
use crate::util::sync::{lock_unpoisoned, wait_timeout_unpoisoned, wait_unpoisoned};

/// Tuning knobs for one model's [`MicroBatcher`].
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Deadline from a group's first enqueue to its forced flush.
    /// `Duration::ZERO` flushes every request immediately.
    pub window: Duration,
    /// Flush as soon as a group reaches this size (normally the model's
    /// engine batch size — larger groups cannot fill an engine batch
    /// any further).
    pub max_batch: usize,
    /// Bound on requests queued ahead of the collector; beyond it,
    /// enqueues are rejected with [`ServeError::Busy`] (backpressure at
    /// the network edge mirrors the engine's bounded shards).
    pub queue_cap: usize,
}

impl BatcherConfig {
    /// Config for a model served by `client`: flush at the engine batch
    /// size, queue at most 4 engine batches ahead.
    pub fn for_client(client: &Client, window: Duration) -> BatcherConfig {
        let max_batch = client.batch().max(1);
        BatcherConfig {
            window,
            max_batch,
            queue_cap: max_batch * 4,
        }
    }
}

/// Coalescing counters for one model's micro-batcher. All atomics,
/// readable at any time with `Ordering::Relaxed`.
#[derive(Debug, Default)]
pub struct BatcherMetrics {
    /// Groups flushed into the engine.
    pub flushes: AtomicU64,
    /// Requests carried by those groups; `coalesced / flushes` is the
    /// achieved mean coalesced batch size.
    pub coalesced: AtomicU64,
    /// Flushes triggered by the group reaching `max_batch`.
    pub full_flushes: AtomicU64,
    /// Flushes triggered by the batch window expiring (or by shutdown
    /// draining a partial group).
    pub deadline_flushes: AtomicU64,
    /// Enqueues rejected because the collector queue was at
    /// [`BatcherConfig::queue_cap`].
    pub rejected: AtomicU64,
    /// Responders that panicked during delivery (each loses only its
    /// own response; the batcher threads survive).
    pub responder_panics: AtomicU64,
}

impl BatcherMetrics {
    /// Achieved mean coalesced batch size (0.0 before any flush).
    pub fn mean_coalesced(&self) -> f64 {
        let f = self.flushes.load(Ordering::Relaxed);
        if f == 0 {
            0.0
        } else {
            self.coalesced.load(Ordering::Relaxed) as f64 / f as f64
        }
    }
}

/// The delivery callback of a [`BatchItem`]: invoked exactly once with
/// the request's outcome, from a batcher thread.
pub type Responder = Box<dyn FnOnce(Result<Prediction, ServeError>) + Send>;

/// Invoke one responder with panic isolation: a panicking callback is
/// counted and absorbed so it cannot take down the batcher thread (and
/// with it every other connection's replies).
fn deliver(metrics: &BatcherMetrics, respond: Responder, res: Result<Prediction, ServeError>) {
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || respond(res)));
    if outcome.is_err() {
        metrics.responder_panics.fetch_add(1, Ordering::Relaxed);
    }
}

/// One queued request: the feature vector plus the callback that
/// delivers its outcome (the socket layer writes a `Response` or
/// `Error` frame from it; tests capture the result directly).
pub struct BatchItem {
    /// Input feature vector (already validated against the model's
    /// input dimension by the caller).
    pub features: Vec<f32>,
    /// Tenant context this request belongs to (already validated
    /// against the model's context count by the caller); the flush
    /// keeps the context attached, the service groups by it at
    /// execution time.
    pub context: usize,
    /// Invoked exactly once with the request's outcome, from a batcher
    /// thread.
    pub respond: Responder,
    /// Live trace for a sampled request: the batcher stamps the queue
    /// and dispatch marks on it and forwards it into the engine, which
    /// finishes it into the [`crate::obs::trace::TraceEcho`] carried on
    /// the prediction. `None` (the common case) costs nothing.
    pub trace: Option<Box<ReqTrace>>,
}

/// A queued request stamped with its arrival time, so the flush
/// deadline of any group is always measured from its *oldest* member —
/// including requests left behind by a full flush.
struct QueuedItem {
    item: BatchItem,
    arrived: Instant,
}

struct BatcherState {
    queue: VecDeque<QueuedItem>,
    stopped: bool,
}

struct BatcherShared {
    client: Client,
    cfg: BatcherConfig,
    state: Mutex<BatcherState>,
    nonempty: Condvar,
    metrics: BatcherMetrics,
}

/// Emit one batcher's counters as registry samples (`batcher.*`,
/// labelled by model).
fn collect_batcher_samples(shared: &BatcherShared, out: &mut Vec<Sample>) {
    let m = &shared.metrics;
    let l = || vec![("model", shared.client.model().to_string())];
    let c = Ordering::Relaxed;
    out.push(Sample::counter("batcher.flushes", l(), m.flushes.load(c)));
    out.push(Sample::counter("batcher.coalesced", l(), m.coalesced.load(c)));
    out.push(Sample::counter("batcher.full_flushes", l(), m.full_flushes.load(c)));
    out.push(Sample::counter(
        "batcher.deadline_flushes",
        l(),
        m.deadline_flushes.load(c),
    ));
    out.push(Sample::counter("batcher.rejected", l(), m.rejected.load(c)));
    out.push(Sample::counter(
        "batcher.responder_panics",
        l(),
        m.responder_panics.load(c),
    ));
    out.push(Sample::gauge("batcher.mean_coalesced", l(), m.mean_coalesced()));
}

/// Cloneable enqueue handle onto a [`MicroBatcher`] (what connection
/// handlers hold; the batcher itself stays owned by the server for
/// shutdown).
#[derive(Clone)]
pub struct BatcherHandle {
    shared: Arc<BatcherShared>,
}

impl BatcherHandle {
    /// Queue one request for the next flush. On rejection (queue cap
    /// reached, or the batcher already stopped) the item's `respond`
    /// callback is invoked immediately with the error — every accepted
    /// call resolves exactly once, on some thread.
    pub fn enqueue(&self, item: BatchItem) {
        let mut item = item;
        if let Some(tr) = item.trace.as_mut() {
            tr.mark_enqueued();
        }
        let err = {
            let mut s = lock_unpoisoned(&self.shared.state);
            if s.stopped {
                Some((ServeError::Stopped, item))
            } else if s.queue.len() >= self.shared.cfg.queue_cap {
                self.shared.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Some((ServeError::Busy, item))
            } else {
                s.queue.push_back(QueuedItem {
                    item,
                    arrived: Instant::now(),
                });
                None
            }
        };
        match err {
            // respond outside the lock: the callback does socket I/O
            Some((e, item)) => deliver(&self.shared.metrics, item.respond, Err(e)),
            None => self.shared.nonempty.notify_one(),
        }
    }

    /// This batcher's coalescing counters.
    pub fn metrics(&self) -> &BatcherMetrics {
        &self.shared.metrics
    }

    /// The model this batcher feeds.
    pub fn model(&self) -> &str {
        self.shared.client.model()
    }

    /// Input feature dimension of the model this batcher feeds.
    pub fn features(&self) -> usize {
        self.shared.client.features()
    }

    /// Number of output classes of the model this batcher feeds.
    pub fn classes(&self) -> usize {
        self.shared.client.classes()
    }

    /// Engine batch size of the model this batcher feeds.
    pub fn batch(&self) -> usize {
        self.shared.client.batch()
    }

    /// Tenant contexts of the model this batcher feeds.
    pub fn contexts(&self) -> usize {
        self.shared.client.contexts()
    }
}

/// One flushed group in flight: the accepted submissions paired with
/// their responders, handed to the completion thread.
struct InFlightGroup {
    items: Vec<(PendingPrediction, Responder)>,
}

/// Per-model adaptive micro-batcher (see the module docs). Owns the
/// collector and completion threads; [`MicroBatcher::shutdown`] drains
/// every accepted request before returning.
pub struct MicroBatcher {
    shared: Arc<BatcherShared>,
    collector: Option<JoinHandle<()>>,
    completer: Option<JoinHandle<()>>,
}

impl MicroBatcher {
    /// Spawn the collector + completion threads for `client`'s model.
    pub fn start(client: Client, cfg: BatcherConfig) -> MicroBatcher {
        let shared = Arc::new(BatcherShared {
            client,
            cfg,
            state: Mutex::new(BatcherState {
                queue: VecDeque::new(),
                stopped: false,
            }),
            nonempty: Condvar::new(),
            metrics: BatcherMetrics::default(),
        });
        let (group_tx, group_rx): (Sender<InFlightGroup>, Receiver<InFlightGroup>) =
            mpsc::channel();
        let collector = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || collector_loop(shared, group_tx))
        };
        let completer = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || completer_loop(shared, group_rx))
        };
        MicroBatcher {
            shared,
            collector: Some(collector),
            completer: Some(completer),
        }
    }

    /// Cloneable enqueue handle for connection handlers.
    pub fn handle(&self) -> BatcherHandle {
        BatcherHandle {
            shared: Arc::clone(&self.shared),
        }
    }

    /// This batcher's coalescing counters.
    pub fn metrics(&self) -> &BatcherMetrics {
        &self.shared.metrics
    }

    /// Register this batcher's counters with `registry` under the
    /// `batcher.*` names, labelled with the model it feeds. The
    /// collector holds a weak reference, so registration never extends
    /// the batcher's lifetime — after shutdown it contributes nothing.
    pub fn register_collector(&self, registry: &Registry) {
        let weak = Arc::downgrade(&self.shared);
        registry.register(move |out| {
            if let Some(shared) = weak.upgrade() {
                collect_batcher_samples(&shared, out);
            }
        });
    }

    /// Begin the drain without blocking: stop accepting new enqueues
    /// (they resolve with [`ServeError::Stopped`]) and make the
    /// collector flush already-queued requests immediately instead of
    /// holding them for the rest of their window. Used by the TCP
    /// server so connection drains are bounded by execution time, not
    /// by the batch-window setting.
    pub fn request_stop(&self) {
        self.signal_stop();
    }

    /// Stop accepting, flush whatever is queued (a partial group is
    /// flushed immediately, not held for its window), wait for every
    /// in-flight response to be delivered, and join both threads.
    pub fn shutdown(mut self) {
        self.signal_stop();
        if let Some(h) = self.collector.take() {
            let _ = h.join();
        }
        // the collector exiting dropped its group sender, so the
        // completion thread drains the channel and exits
        if let Some(h) = self.completer.take() {
            let _ = h.join();
        }
    }

    fn signal_stop(&self) {
        lock_unpoisoned(&self.shared.state).stopped = true;
        self.shared.nonempty.notify_all();
    }
}

impl Drop for MicroBatcher {
    /// Dropping without [`MicroBatcher::shutdown`] still signals the
    /// threads to stop; they drain detached rather than joined.
    fn drop(&mut self) {
        self.signal_stop();
    }
}

/// Collect-and-flush loop: block for a group's first request, then
/// fill until `max_batch` or the window deadline, then dispatch the
/// whole group into the engine shards back-to-back.
fn collector_loop(shared: Arc<BatcherShared>, groups: Sender<InFlightGroup>) {
    loop {
        let (group, full) = {
            let mut s = lock_unpoisoned(&shared.state);
            // wait for the first request of a group (or stop + empty)
            loop {
                if !s.queue.is_empty() || s.stopped {
                    break;
                }
                // spurious wakeups just re-check the predicate
                s = wait_unpoisoned(&shared.nonempty, s);
            }
            if s.queue.is_empty() {
                // stopped and drained: done
                return;
            }
            // fill until full, deadline, or stop (stop flushes the
            // partial group immediately so shutdown never waits a
            // whole window). The deadline is measured from the oldest
            // queued request's own arrival, so a request left behind
            // by a previous full flush never waits more than one
            // window in total.
            let deadline = s.queue.front().map(|q| q.arrived).unwrap_or_else(Instant::now)
                + shared.cfg.window;
            while s.queue.len() < shared.cfg.max_batch && !s.stopped {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, _timeout) =
                    wait_timeout_unpoisoned(&shared.nonempty, s, deadline - now);
                s = guard;
            }
            let take = s.queue.len().min(shared.cfg.max_batch);
            let group: Vec<BatchItem> = s.queue.drain(..take).map(|q| q.item).collect();
            (group, take >= shared.cfg.max_batch)
        };
        // dispatch outside the lock: back-to-back submits land in the
        // worker shards together, which is what turns this group into
        // full engine batches downstream
        let mut in_flight = Vec::with_capacity(group.len());
        for item in group {
            let BatchItem {
                features,
                context,
                respond,
                mut trace,
            } = item;
            if let Some(tr) = trace.as_mut() {
                tr.mark_dispatched();
            }
            match shared.client.submit_ctx_traced(features, context, trace) {
                Ok(pending) => in_flight.push((pending, respond)),
                Err(e) => deliver(&shared.metrics, respond, Err(e)),
            }
        }
        if !in_flight.is_empty() {
            // count the flush AFTER dispatch and only over accepted
            // submits: mean_coalesced() is the acceptance metric
            // claiming traffic reached the engine as batches, so work
            // the engine shed with Busy/Stopped must not inflate it
            let m = &shared.metrics;
            m.flushes.fetch_add(1, Ordering::Relaxed);
            m.coalesced.fetch_add(in_flight.len() as u64, Ordering::Relaxed);
            if full {
                m.full_flushes.fetch_add(1, Ordering::Relaxed);
            } else {
                m.deadline_flushes.fetch_add(1, Ordering::Relaxed);
            }
            if let Err(failed) = groups.send(InFlightGroup { items: in_flight }) {
                // completion thread is gone (responders run under
                // catch_unwind, so only a killed process side exits it
                // early): the exactly-once contract still holds —
                // resolve every stranded responder with Stopped instead
                // of silently dropping it, so connection handlers and
                // tests never wait on a reply that cannot come. The
                // workers tolerate the abandoned predictions (their
                // reply send fails harmlessly).
                for (pending, respond) in failed.0.items {
                    drop(pending);
                    deliver(&shared.metrics, respond, Err(ServeError::Stopped));
                }
                return;
            }
        }
    }
}

/// Deliver engine results group by group. Within a group the waits are
/// sequential, which is fine: the group executed together, so by the
/// time the first reply arrives the rest are computed or imminent.
/// Every delivery is panic-isolated (see [`deliver`]): one broken
/// responder loses only its own response, never the loop.
fn completer_loop(shared: Arc<BatcherShared>, groups: Receiver<InFlightGroup>) {
    while let Ok(group) = groups.recv() {
        for (pending, respond) in group.items {
            let res = pending.wait();
            deliver(&shared.metrics, respond, res);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    use crate::coordinator::loadgen::model_spec;
    use crate::coordinator::{InferenceService, ServerConfig};

    fn dir() -> &'static str {
        concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")
    }

    /// A wide window plus a burst of enqueues must coalesce into one
    /// flush, and every request must resolve exactly once.
    #[test]
    fn burst_coalesces_into_one_flush() {
        let spec = model_spec(dir(), "tiny", 0.25, 21).unwrap();
        let svc =
            InferenceService::start(dir(), vec![spec], ServerConfig::default()).unwrap();
        let client = svc.client("tiny").unwrap();
        let features = client.features();
        let batcher = MicroBatcher::start(
            client,
            BatcherConfig {
                window: Duration::from_millis(200),
                max_batch: 16,
                queue_cap: 64,
            },
        );
        let handle = batcher.handle();
        let (tx, rx) = channel();
        let n = 8usize;
        for _ in 0..n {
            let tx = tx.clone();
            handle.enqueue(BatchItem {
                features: vec![0.25; features],
                context: 0,
                trace: None,
                respond: Box::new(move |res| tx.send(res.map(|p| p.class)).unwrap()),
            });
        }
        for _ in 0..n {
            let class = rx
                .recv_timeout(Duration::from_secs(10))
                .expect("every request resolves")
                .expect("prediction ok");
            assert!(class < 8);
        }
        let m = batcher.metrics();
        assert_eq!(m.coalesced.load(Ordering::Relaxed), n as u64);
        assert_eq!(
            m.flushes.load(Ordering::Relaxed),
            1,
            "burst inside one window must be one flush"
        );
        assert!(m.mean_coalesced() > 1.0);
        batcher.shutdown();
        svc.shutdown().unwrap();
    }

    /// Shutdown must drain accepted requests (partial group flushed
    /// immediately) and reject later enqueues with `Stopped`.
    #[test]
    fn shutdown_drains_accepted_and_rejects_late() {
        let spec = model_spec(dir(), "tiny", 0.25, 22).unwrap();
        let svc =
            InferenceService::start(dir(), vec![spec], ServerConfig::default()).unwrap();
        let client = svc.client("tiny").unwrap();
        let features = client.features();
        let batcher = MicroBatcher::start(
            client,
            BatcherConfig {
                // a window far longer than the test: only the shutdown
                // drain can flush these
                window: Duration::from_secs(60),
                max_batch: 16,
                queue_cap: 64,
            },
        );
        let handle = batcher.handle();
        let (tx, rx) = channel();
        for _ in 0..3 {
            let tx = tx.clone();
            handle.enqueue(BatchItem {
                features: vec![0.1; features],
                context: 0,
                trace: None,
                respond: Box::new(move |res| tx.send(res.is_ok()).unwrap()),
            });
        }
        batcher.shutdown();
        for _ in 0..3 {
            assert!(
                rx.recv_timeout(Duration::from_secs(10)).unwrap(),
                "accepted requests must be served by the drain"
            );
        }
        let (tx2, rx2) = channel();
        handle.enqueue(BatchItem {
            features: vec![0.1; features],
            context: 0,
            trace: None,
            respond: Box::new(move |res| {
                tx2.send(matches!(res, Err(ServeError::Stopped))).unwrap()
            }),
        });
        assert!(rx2.recv_timeout(Duration::from_secs(10)).unwrap());
        svc.shutdown().unwrap();
    }

    /// The queue cap sheds with `Busy` instead of growing unbounded.
    #[test]
    fn queue_cap_rejects_with_busy() {
        let spec = model_spec(dir(), "tiny", 0.25, 23).unwrap();
        let svc =
            InferenceService::start(dir(), vec![spec], ServerConfig::default()).unwrap();
        let client = svc.client("tiny").unwrap();
        let features = client.features();
        let batcher = MicroBatcher::start(
            client,
            BatcherConfig {
                window: Duration::from_secs(60),
                max_batch: 1000, // never full-flush during the test
                queue_cap: 4,
            },
        );
        let handle = batcher.handle();
        let (tx, rx) = channel();
        let mut busy = 0usize;
        for _ in 0..8 {
            let tx = tx.clone();
            handle.enqueue(BatchItem {
                features: vec![0.0; features],
                context: 0,
                trace: None,
                respond: Box::new(move |res| {
                    tx.send(matches!(res, Err(ServeError::Busy))).unwrap()
                }),
            });
        }
        // the cap is 4 and the collector may drain some before later
        // enqueues, so at least 8 - 4 - (drained) rejections... the
        // collector holds its group for the 60 s window, so exactly the
        // overflow beyond one in-progress group is rejected; count the
        // immediate Busy responses (they resolve synchronously)
        while let Ok(was_busy) = rx.try_recv() {
            if was_busy {
                busy += 1;
            }
        }
        assert!(busy >= 1, "overflow beyond the cap must shed as Busy");
        assert_eq!(
            batcher.metrics().rejected.load(Ordering::Relaxed),
            busy as u64
        );
        batcher.shutdown();
        svc.shutdown().unwrap();
    }

    /// One panicking responder must lose only its own response: later
    /// requests through the same batcher still resolve, and the panic
    /// is counted — the "one failing connection cannot take down the
    /// server" guarantee at the batcher layer.
    #[test]
    fn panicking_responder_does_not_kill_the_batcher() {
        let spec = model_spec(dir(), "tiny", 0.25, 24).unwrap();
        let svc =
            InferenceService::start(dir(), vec![spec], ServerConfig::default()).unwrap();
        let client = svc.client("tiny").unwrap();
        let features = client.features();
        let batcher = MicroBatcher::start(
            client,
            BatcherConfig {
                window: Duration::from_millis(1),
                max_batch: 16,
                queue_cap: 64,
            },
        );
        let handle = batcher.handle();
        handle.enqueue(BatchItem {
            features: vec![0.5; features],
            context: 0,
            trace: None,
            respond: Box::new(|_res| panic!("deliberately broken responder")),
        });
        // the poisoned delivery must not stop this one from resolving
        let (tx, rx) = channel();
        handle.enqueue(BatchItem {
            features: vec![0.5; features],
            context: 0,
            trace: None,
            respond: Box::new(move |res| tx.send(res.map(|p| p.class)).unwrap()),
        });
        let class = rx
            .recv_timeout(Duration::from_secs(10))
            .expect("batcher must survive a panicking responder")
            .expect("prediction ok");
        assert!(class < 8);
        assert_eq!(
            batcher.metrics().responder_panics.load(Ordering::Relaxed),
            1,
            "the panic must be counted"
        );
        batcher.shutdown();
        svc.shutdown().unwrap();
    }
}
