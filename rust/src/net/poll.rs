//! Minimal readiness-polling abstraction for the reactor front-end.
//!
//! The crate builds offline with no async runtime and no `libc` crate,
//! so this module wraps the one OS primitive the event loop needs —
//! "which of these sockets are readable/writable?" — behind the
//! [`Poller`] trait:
//!
//! - On Unix, [`new_poller`] returns a thin FFI wrapper over `poll(2)`
//!   (declared directly; the symbol comes from the libc the standard
//!   library already links). Level-triggered, O(n) per call — the right
//!   trade for thousands of mostly-idle connections without pulling in
//!   an epoll/kqueue abstraction layer.
//! - Elsewhere it falls back to [`TickPoller`], a portable
//!   sleep-and-report poller that claims readiness for every registered
//!   source at a small tick. Degenerate but *correct*: all sockets in
//!   the reactor are nonblocking, so a spurious readiness report costs
//!   one `WouldBlock` syscall, never a stall.
//!
//! Registration is keyed by caller-chosen [`Token`]s (the reactor's
//! slab indices), not file descriptors, so the portable fallback needs
//! no OS identity for a socket.
//!
//! [`wake_pair`] builds the reactor's waker: a connected loopback UDP
//! socket pair whose receive side sits in the poll set. Batcher
//! completion threads call [`Waker::wake`] after queueing response
//! frames; an `AtomicBool` coalesces storms of wakes into (at most) one
//! in-flight datagram, and a lost datagram under send-buffer pressure
//! is harmless — a full buffer implies queued datagrams that already
//! make the receive side readable.

use std::io;
use std::net::UdpSocket;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Caller-chosen identity of a registered source (the reactor uses its
/// connection-slab index). Unique per [`Poller`] at any instant.
pub type Token = usize;

/// OS-level identity of a pollable socket.
#[cfg(unix)]
pub type SourceId = std::os::unix::io::RawFd;
/// OS-level identity of a pollable socket.
#[cfg(all(not(unix), windows))]
pub type SourceId = u64;
/// OS-level identity of a pollable socket (unused by the portable
/// fallback poller, which keys purely on tokens).
#[cfg(all(not(unix), not(windows)))]
pub type SourceId = usize;

/// Extract the [`SourceId`] of a socket for [`Poller::register`].
#[cfg(unix)]
pub fn source<T: std::os::unix::io::AsRawFd>(s: &T) -> SourceId {
    s.as_raw_fd()
}

/// Extract the [`SourceId`] of a socket for [`Poller::register`].
#[cfg(all(not(unix), windows))]
pub fn source<T: std::os::windows::io::AsRawSocket>(s: &T) -> SourceId {
    s.as_raw_socket()
}

/// Extract the [`SourceId`] of a socket for [`Poller::register`]. The
/// portable fallback poller never consults it.
#[cfg(all(not(unix), not(windows)))]
pub fn source<T>(_s: &T) -> SourceId {
    0
}

/// Which readiness directions a registration asks for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Interest {
    /// Report when a read would make progress (data, EOF, or error).
    pub read: bool,
    /// Report when a write would make progress.
    pub write: bool,
}

impl Interest {
    /// Read readiness only.
    pub const READ: Interest = Interest { read: true, write: false };
    /// Write readiness only.
    pub const WRITE: Interest = Interest { read: false, write: true };
    /// Both directions.
    pub const BOTH: Interest = Interest { read: true, write: true };
    /// Neither direction — the source stays registered but silent
    /// (used to mask the listener during accept-error backoff).
    pub const NONE: Interest = Interest { read: false, write: false };
}

/// One readiness report from [`Poller::poll`].
#[derive(Clone, Copy, Debug)]
pub struct PollEvent {
    /// The registration this event belongs to.
    pub token: Token,
    /// A read would make progress (includes hangup: the read returns
    /// EOF or the pending error, which is progress).
    pub readable: bool,
    /// A write would make progress.
    pub writable: bool,
    /// The source is in an error state (`POLLERR`/`POLLNVAL`); the
    /// owner should read out the error and close.
    pub error: bool,
}

/// A readiness poller over a set of registered sockets. One instance
/// per reactor thread; not shared.
pub trait Poller: Send {
    /// Start watching `src` under `token`. The token must not already
    /// be registered.
    fn register(&mut self, src: SourceId, token: Token, interest: Interest) -> io::Result<()>;
    /// Change the interest set of an existing registration. Unknown
    /// tokens are ignored.
    fn reregister(&mut self, token: Token, interest: Interest) -> io::Result<()>;
    /// Stop watching a registration. Unknown tokens are ignored.
    fn deregister(&mut self, token: Token) -> io::Result<()>;
    /// Block until at least one registered source is ready or `timeout`
    /// elapses (`None` = wait indefinitely), then append the ready set
    /// to `events` (cleared first). A timeout yields an empty set.
    fn poll(&mut self, events: &mut Vec<PollEvent>, timeout: Option<Duration>) -> io::Result<()>;
}

/// Construct the best poller for this platform: `poll(2)` on Unix, the
/// tick-based fallback elsewhere.
pub fn new_poller() -> Box<dyn Poller> {
    #[cfg(unix)]
    {
        Box::new(PollFdPoller::new())
    }
    #[cfg(not(unix))]
    {
        Box::new(TickPoller::new())
    }
}

/// Round a timeout up to whole milliseconds for `poll(2)` (rounding
/// *down* could turn a sub-millisecond deadline into a hot spin).
#[cfg(unix)]
fn timeout_ms(timeout: Option<Duration>) -> i32 {
    match timeout {
        None => -1,
        Some(d) => {
            let ms = d.as_millis();
            let ms = if Duration::from_millis(u64::try_from(ms).unwrap_or(u64::MAX)) < d {
                ms + 1
            } else {
                ms
            };
            i32::try_from(ms).unwrap_or(i32::MAX)
        }
    }
}

#[cfg(unix)]
mod sys {
    //! Direct declaration of `poll(2)`. The crate deliberately has no
    //! `libc` dependency (offline build); the standard library already
    //! links the platform libc, so declaring the symbol is enough.

    /// `struct pollfd` as declared by POSIX; identical layout on every
    /// supported Unix.
    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct PollFd {
        pub fd: super::SourceId,
        pub events: i16,
        pub revents: i16,
    }

    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;
    pub const POLLNVAL: i16 = 0x020;

    /// `nfds_t`: `unsigned long` on Linux (pointer-width), `unsigned
    /// int` on the BSD family. Either way the value is a small count.
    #[cfg(target_os = "linux")]
    pub type Nfds = usize;
    /// `nfds_t` on non-Linux Unix.
    #[cfg(not(target_os = "linux"))]
    pub type Nfds = u32;

    extern "C" {
        pub fn poll(fds: *mut PollFd, nfds: Nfds, timeout: i32) -> i32;
    }
}

/// `poll(2)`-backed [`Poller`]: a dense `pollfd` array plus a parallel
/// token array, O(1) register/deregister by swap-remove.
#[cfg(unix)]
pub struct PollFdPoller {
    fds: Vec<sys::PollFd>,
    tokens: Vec<Token>,
}

#[cfg(unix)]
impl PollFdPoller {
    /// Empty poll set.
    pub fn new() -> PollFdPoller {
        PollFdPoller { fds: Vec::new(), tokens: Vec::new() }
    }

    fn events_for(interest: Interest) -> i16 {
        let mut e = 0i16;
        if interest.read {
            e |= sys::POLLIN;
        }
        if interest.write {
            e |= sys::POLLOUT;
        }
        e
    }

    fn position(&self, token: Token) -> Option<usize> {
        self.tokens.iter().position(|&t| t == token)
    }
}

#[cfg(unix)]
impl Default for PollFdPoller {
    fn default() -> Self {
        PollFdPoller::new()
    }
}

#[cfg(unix)]
impl Poller for PollFdPoller {
    fn register(&mut self, src: SourceId, token: Token, interest: Interest) -> io::Result<()> {
        debug_assert!(self.position(token).is_none(), "token registered twice");
        self.fds.push(sys::PollFd {
            fd: src,
            events: Self::events_for(interest),
            revents: 0,
        });
        self.tokens.push(token);
        Ok(())
    }

    fn reregister(&mut self, token: Token, interest: Interest) -> io::Result<()> {
        if let Some(i) = self.position(token) {
            self.fds[i].events = Self::events_for(interest);
        }
        Ok(())
    }

    fn deregister(&mut self, token: Token) -> io::Result<()> {
        if let Some(i) = self.position(token) {
            self.fds.swap_remove(i);
            self.tokens.swap_remove(i);
        }
        Ok(())
    }

    fn poll(&mut self, events: &mut Vec<PollEvent>, timeout: Option<Duration>) -> io::Result<()> {
        events.clear();
        for f in &mut self.fds {
            f.revents = 0;
        }
        let rc = unsafe {
            sys::poll(
                self.fds.as_mut_ptr(),
                self.fds.len() as sys::Nfds,
                timeout_ms(timeout),
            )
        };
        if rc < 0 {
            let e = io::Error::last_os_error();
            if e.kind() == io::ErrorKind::Interrupted {
                return Ok(()); // signal: report no events, caller re-loops
            }
            return Err(e);
        }
        for (f, &token) in self.fds.iter().zip(&self.tokens) {
            if f.revents == 0 {
                continue;
            }
            let error = f.revents & (sys::POLLERR | sys::POLLNVAL) != 0;
            events.push(PollEvent {
                token,
                // hangup and error states count as readable: the next
                // read returns EOF / the pending error, which is how
                // the connection layer learns the peer is gone
                readable: f.revents & (sys::POLLIN | sys::POLLHUP) != 0 || error,
                writable: f.revents & sys::POLLOUT != 0,
                error,
            });
        }
        Ok(())
    }
}

/// Portable fallback [`Poller`]: sleeps up to one tick, then reports
/// every registered source ready per its interest. Degenerate (every
/// tick costs one syscall per connection) but correct against
/// nonblocking sockets, which simply return `WouldBlock` when a
/// readiness claim was premature. Compiled on every platform so the
/// fallback cannot bit-rot; selected by [`new_poller`] only off-Unix.
pub struct TickPoller {
    entries: Vec<(Token, Interest)>,
    tick: Duration,
}

impl TickPoller {
    /// Fallback poller with a 1 ms tick.
    pub fn new() -> TickPoller {
        TickPoller { entries: Vec::new(), tick: Duration::from_millis(1) }
    }
}

impl Default for TickPoller {
    fn default() -> Self {
        TickPoller::new()
    }
}

impl Poller for TickPoller {
    fn register(&mut self, _src: SourceId, token: Token, interest: Interest) -> io::Result<()> {
        debug_assert!(
            !self.entries.iter().any(|&(t, _)| t == token),
            "token registered twice"
        );
        self.entries.push((token, interest));
        Ok(())
    }

    fn reregister(&mut self, token: Token, interest: Interest) -> io::Result<()> {
        if let Some(e) = self.entries.iter_mut().find(|(t, _)| *t == token) {
            e.1 = interest;
        }
        Ok(())
    }

    fn deregister(&mut self, token: Token) -> io::Result<()> {
        self.entries.retain(|&(t, _)| t != token);
        Ok(())
    }

    fn poll(&mut self, events: &mut Vec<PollEvent>, timeout: Option<Duration>) -> io::Result<()> {
        events.clear();
        let nap = match timeout {
            None => self.tick,
            Some(t) => t.min(self.tick),
        };
        if !nap.is_zero() {
            std::thread::sleep(nap);
        }
        for &(token, interest) in &self.entries {
            if interest.read || interest.write {
                events.push(PollEvent {
                    token,
                    readable: interest.read,
                    writable: interest.write,
                    error: false,
                });
            }
        }
        Ok(())
    }
}

/// Wake handle held by threads outside the reactor (batcher completion
/// threads, [`crate::net::NetServer`] shutdown). Cheap to call from any
/// thread; redundant wakes coalesce.
pub struct Waker {
    tx: UdpSocket,
    pending: Arc<AtomicBool>,
}

impl Waker {
    /// Make the reactor's next (or current) poll return. At most one
    /// datagram is in flight per quiet period: wakes between the send
    /// and the reactor's drain fold into the pending flag.
    pub fn wake(&self) {
        if !self.pending.swap(true, Ordering::AcqRel) {
            // a failed send (full buffer) is safe: a full buffer means
            // queued datagrams already make the receive side readable
            let _ = self.tx.send(&[1u8]);
        }
    }
}

/// Reactor-side end of the waker: registered in the poll set; drained
/// once per readiness report.
pub struct WakeReceiver {
    rx: UdpSocket,
    pending: Arc<AtomicBool>,
}

impl WakeReceiver {
    /// OS identity for [`Poller::register`].
    pub fn source(&self) -> SourceId {
        source(&self.rx)
    }

    /// Absorb queued wake datagrams and rearm the coalescing flag.
    /// Clearing the flag *before* reading means a wake racing this
    /// drain at worst leaves one extra queued datagram (a spurious
    /// poll wake-up), never an unobserved wake.
    pub fn drain(&self) {
        self.pending.store(false, Ordering::Release);
        let mut scratch = [0u8; 16];
        while self.rx.recv(&mut scratch).is_ok() {}
    }
}

/// Build a connected loopback UDP waker pair (see the module docs for
/// why UDP: portable, std-only, datagram loss under pressure is safe).
pub fn wake_pair() -> io::Result<(Waker, WakeReceiver)> {
    let rx = UdpSocket::bind("127.0.0.1:0")?;
    let tx = UdpSocket::bind("127.0.0.1:0")?;
    tx.connect(rx.local_addr()?)?;
    // the receive side must never block the reactor; the send side must
    // never block a batcher thread on a full buffer
    rx.set_nonblocking(true)?;
    tx.set_nonblocking(true)?;
    let pending = Arc::new(AtomicBool::new(false));
    Ok((
        Waker { tx, pending: Arc::clone(&pending) },
        WakeReceiver { rx, pending },
    ))
}

/// Upper bound on how long the reactor may sleep given the next armed
/// deadline: `None` when `deadline` is unset (sleep until an event).
pub fn timeout_until(deadline: Option<Instant>, now: Instant) -> Option<Duration> {
    deadline.map(|d| d.saturating_duration_since(now))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::{TcpListener, TcpStream};

    fn tcp_pair() -> (TcpStream, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let a = TcpStream::connect(addr).unwrap();
        let (b, _) = listener.accept().unwrap();
        (a, b)
    }

    #[test]
    fn reports_writable_then_readable() {
        let (a, mut b) = tcp_pair();
        a.set_nonblocking(true).unwrap();
        let mut p = new_poller();
        p.register(source(&a), 7, Interest::BOTH).unwrap();
        let mut events = Vec::new();
        // a fresh socket with an empty send buffer is writable
        p.poll(&mut events, Some(Duration::from_secs(5))).unwrap();
        let ev = events.iter().find(|e| e.token == 7).expect("event for token 7");
        assert!(ev.writable, "fresh socket must be writable");
        // nothing to read yet -> after the peer writes, readable
        b.write_all(b"ping").unwrap();
        b.flush().unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            p.poll(&mut events, Some(Duration::from_millis(100))).unwrap();
            if events.iter().any(|e| e.token == 7 && e.readable) {
                break;
            }
            assert!(Instant::now() < deadline, "readable never reported");
        }
        let mut buf = [0u8; 8];
        let n = (&a).read(&mut buf).unwrap();
        assert_eq!(&buf[..n], b"ping");
    }

    #[test]
    fn deregistered_token_goes_silent() {
        let (a, mut b) = tcp_pair();
        a.set_nonblocking(true).unwrap();
        let mut p = new_poller();
        p.register(source(&a), 3, Interest::READ).unwrap();
        b.write_all(b"x").unwrap();
        let mut events = Vec::new();
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            p.poll(&mut events, Some(Duration::from_millis(100))).unwrap();
            if events.iter().any(|e| e.token == 3 && e.readable) {
                break;
            }
            assert!(Instant::now() < deadline, "readable never reported");
        }
        p.deregister(3).unwrap();
        p.poll(&mut events, Some(Duration::from_millis(50))).unwrap();
        assert!(
            !events.iter().any(|e| e.token == 3),
            "deregistered token must not report"
        );
    }

    #[test]
    fn waker_interrupts_a_long_poll() {
        let (waker, rx) = wake_pair().unwrap();
        let mut p = new_poller();
        p.register(rx.source(), 1, Interest::READ).unwrap();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(50));
            waker.wake();
            waker.wake(); // the second wake coalesces into the first
            waker
        });
        let mut events = Vec::new();
        let t0 = Instant::now();
        // the unix poller sleeps the full timeout unless woken; the
        // tick poller wakes every tick regardless, so loop on readable
        let deadline = t0 + Duration::from_secs(10);
        loop {
            p.poll(&mut events, Some(Duration::from_secs(10))).unwrap();
            if events.iter().any(|e| e.token == 1 && e.readable) {
                break;
            }
            assert!(Instant::now() < deadline, "wake never observed");
        }
        assert!(
            t0.elapsed() < Duration::from_secs(9),
            "poll must return early on wake"
        );
        rx.drain();
        let _ = t.join().unwrap();
    }

    #[test]
    fn tick_poller_claims_readiness_for_registered_interest() {
        // exercised on every platform so the off-unix fallback cannot rot
        let mut p = TickPoller::new();
        p.register(0, 11, Interest::READ).unwrap();
        p.register(0, 12, Interest::WRITE).unwrap();
        p.register(0, 13, Interest::NONE).unwrap();
        let mut events = Vec::new();
        p.poll(&mut events, Some(Duration::from_millis(5))).unwrap();
        let r = events.iter().find(|e| e.token == 11).unwrap();
        assert!(r.readable && !r.writable);
        let w = events.iter().find(|e| e.token == 12).unwrap();
        assert!(w.writable && !w.readable);
        assert!(!events.iter().any(|e| e.token == 13), "masked source is silent");
        p.reregister(12, Interest::NONE).unwrap();
        p.deregister(11).unwrap();
        p.poll(&mut events, Some(Duration::from_millis(5))).unwrap();
        assert!(events.is_empty());
    }

    #[test]
    fn timeout_rounding_never_spins() {
        #[cfg(unix)]
        {
            assert_eq!(timeout_ms(None), -1);
            assert_eq!(timeout_ms(Some(Duration::ZERO)), 0);
            assert_eq!(timeout_ms(Some(Duration::from_micros(10))), 1);
            assert_eq!(timeout_ms(Some(Duration::from_millis(7))), 7);
        }
        let now = Instant::now();
        assert_eq!(timeout_until(None, now), None);
        assert_eq!(timeout_until(Some(now), now + Duration::from_secs(1)), Some(Duration::ZERO));
    }
}
