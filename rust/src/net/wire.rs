//! Length-prefixed binary wire protocol for the networked serving layer.
//!
//! Every frame on the wire is an 8-byte header followed by a payload:
//!
//! ```text
//! offset  size  field
//! 0       2     magic  "PD" (0x50 0x44)
//! 2       1     protocol version (currently 4)
//! 3       1     frame type tag (see the table on [`Frame`])
//! 4       4     payload length, u32 little-endian
//! 8       len   payload (per-type layout, all integers little-endian)
//! ```
//!
//! The decoder is *strict*: a frame with a bad magic, an unknown
//! version, an unknown type tag, a declared payload longer than
//! [`MAX_PAYLOAD`], payload bytes left over after the typed decode, or
//! any out-of-range read inside the payload is rejected with a typed
//! [`WireError`] — never a panic, and never a partial frame. Strings
//! are u16-length-prefixed UTF-8; feature vectors are u32-count-prefixed
//! f32 words (bit-exact round-trip: values go through
//! `to_le_bytes`/`from_le_bytes`, never a numeric conversion).
//!
//! The codec is pure (`Frame::encode` / `Frame::decode` work on byte
//! slices) so the property tests in `tests/prop_net.rs` can exercise
//! truncation, bit flips and oversized headers without sockets;
//! [`read_frame`] / [`write_frame`] adapt it to `std::io` streams.

// codec boundary: every narrowing cast here writes a length field whose
// range is enforced by an assert or a size invariant just above it, so
// each site carries a targeted allow with its argument — a new
// unannotated cast is a bug until proven otherwise
#![deny(clippy::cast_possible_truncation)]
#![deny(clippy::lossy_float_literal)]

use std::collections::BTreeMap;
use std::io::{Read, Write};

use crate::obs::trace::TraceEcho;
use crate::util::json::Json;

/// First two header bytes of every frame.
pub const MAGIC: [u8; 2] = *b"PD";
/// Protocol version this build speaks. Frames carrying any other
/// version are rejected with [`WireError::UnknownVersion`]. Version 2
/// added the tenant-context dimension: a `context` field on `Request`,
/// `contexts` on [`ModelInfo`] and [`MetricsSnapshot`]. Version 3
/// added the reactor's server-level counters to [`MetricsSnapshot`]:
/// `net_accept_errors` and `net_shed_connections` (the strict decoder
/// rejects trailing bytes, so any snapshot layout change is a lockstep
/// version bump). Version 4 added the optional trace fields for sampled
/// request tracing: a trailing flag + trace_id on `Request` and a
/// trailing flag + [`TraceEcho`] (trace_id, queue/batch/execute µs) on
/// `Response`.
pub const VERSION: u8 = 4;
/// Fixed header size in bytes (magic + version + type + payload length).
pub const HEADER_LEN: usize = 8;
/// Hard cap on the declared payload length. A header announcing more is
/// rejected *before* any allocation ([`WireError::Oversized`]), so a
/// hostile 4 GiB length field cannot balloon server memory.
pub const MAX_PAYLOAD: usize = 1 << 20;

/// How many consecutive read timeouts [`read_frame`] tolerates in the
/// *middle* of a frame before giving up with [`WireError::Truncated`].
/// A peer that stalls mid-frame holds a connection handler hostage;
/// this bounds the hostage time to `limit x read_timeout` (about 5 s at
/// the server's 100 ms read timeout) without ever abandoning partially
/// consumed bytes.
const MID_FRAME_STALL_LIMIT: usize = 50;

/// Frame type tags (one per [`Frame`] variant).
const T_REQUEST: u8 = 1;
const T_RESPONSE: u8 = 2;
const T_ERROR: u8 = 3;
const T_HEALTH_REQUEST: u8 = 4;
const T_HEALTH_REPLY: u8 = 5;
const T_METRICS_REQUEST: u8 = 6;
const T_METRICS_REPLY: u8 = 7;
const T_SHUTDOWN: u8 = 8;

/// Why a request failed, as carried by [`Frame::Error`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorCode {
    /// The service (or the connection cap) is at capacity — explicit
    /// backpressure, retry later.
    Busy,
    /// The service has shut down (or is draining and no longer accepts
    /// new requests).
    Stopped,
    /// The request was structurally invalid (wrong feature dimension,
    /// undecodable frame, unexpected frame type).
    BadRequest,
    /// The named model is not served.
    UnknownModel,
    /// An internal server failure.
    Internal,
}

impl ErrorCode {
    fn as_u8(self) -> u8 {
        match self {
            ErrorCode::Busy => 1,
            ErrorCode::Stopped => 2,
            ErrorCode::BadRequest => 3,
            ErrorCode::UnknownModel => 4,
            ErrorCode::Internal => 5,
        }
    }

    fn from_u8(v: u8) -> Option<ErrorCode> {
        match v {
            1 => Some(ErrorCode::Busy),
            2 => Some(ErrorCode::Stopped),
            3 => Some(ErrorCode::BadRequest),
            4 => Some(ErrorCode::UnknownModel),
            5 => Some(ErrorCode::Internal),
            _ => None,
        }
    }
}

impl std::fmt::Display for ErrorCode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            ErrorCode::Busy => "busy",
            ErrorCode::Stopped => "stopped",
            ErrorCode::BadRequest => "bad-request",
            ErrorCode::UnknownModel => "unknown-model",
            ErrorCode::Internal => "internal",
        };
        write!(f, "{s}")
    }
}

/// Shape info for one served model, carried by [`Frame::HealthReply`]
/// so a client can size feature vectors without out-of-band knowledge.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelInfo {
    /// Manifest config name.
    pub name: String,
    /// Input feature dimension.
    pub features: u32,
    /// Number of output classes.
    pub classes: u32,
    /// Compiled engine batch size (the micro-batcher's flush bound).
    pub batch: u32,
    /// Tenant contexts the model hosts; request `context` fields must
    /// be below this.
    pub contexts: u32,
}

/// One model's serving counters, carried by [`Frame::MetricsReply`].
/// Mirrors [`crate::coordinator::ModelMetrics`] plus the network
/// micro-batcher's coalescing counters.
#[derive(Clone, Debug, PartialEq)]
pub struct MetricsSnapshot {
    /// Manifest config name.
    pub model: String,
    /// Requests served (responses actually sent by the engine workers).
    pub requests: u64,
    /// Submissions rejected with `Busy` backpressure.
    pub rejected: u64,
    /// Engine batches executed.
    pub batches: u64,
    /// Zero rows padded into partial engine batches.
    pub padded_rows: u64,
    /// Requests stolen across worker shards.
    pub stolen: u64,
    /// Saturated fixed-point outputs (zero on f32-served models).
    pub quant_saturations: u64,
    /// Median submit-to-reply latency, microseconds.
    pub p50_us: u64,
    /// 95th-percentile latency, microseconds.
    pub p95_us: u64,
    /// 99th-percentile latency, microseconds.
    pub p99_us: u64,
    /// Mean live rows per executed engine batch.
    pub mean_occupancy: f64,
    /// Micro-batcher flushes for this model (socket path only).
    pub net_flushes: u64,
    /// Requests coalesced across those flushes; `net_coalesced /
    /// net_flushes` is the achieved mean coalesced batch size.
    pub net_coalesced: u64,
    /// Transient `accept()` failures at the server's reactor (a
    /// server-level counter, identical in every model's snapshot).
    pub net_accept_errors: u64,
    /// Connections shed at the connection cap with `Error{Busy}` (a
    /// server-level counter, identical in every model's snapshot).
    pub net_shed_connections: u64,
    /// Tenant contexts the model hosts (1 = single-tenant).
    pub contexts: u64,
}

impl MetricsSnapshot {
    /// Achieved mean coalesced batch size at the network micro-batcher
    /// (0.0 before any flush).
    pub fn mean_coalesced(&self) -> f64 {
        if self.net_flushes == 0 {
            0.0
        } else {
            self.net_coalesced as f64 / self.net_flushes as f64
        }
    }

    /// Stable JSON form of the snapshot (one key per wire field, plus
    /// the derived `mean_coalesced`). Used by `pds client
    /// --metrics-json` and validated against a pinned schema in CI.
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("model".into(), Json::Str(self.model.clone()));
        o.insert("requests".into(), Json::Num(self.requests as f64));
        o.insert("rejected".into(), Json::Num(self.rejected as f64));
        o.insert("batches".into(), Json::Num(self.batches as f64));
        o.insert("padded_rows".into(), Json::Num(self.padded_rows as f64));
        o.insert("stolen".into(), Json::Num(self.stolen as f64));
        o.insert("quant_saturations".into(), Json::Num(self.quant_saturations as f64));
        o.insert("p50_us".into(), Json::Num(self.p50_us as f64));
        o.insert("p95_us".into(), Json::Num(self.p95_us as f64));
        o.insert("p99_us".into(), Json::Num(self.p99_us as f64));
        o.insert("mean_occupancy".into(), Json::Num(self.mean_occupancy));
        o.insert("net_flushes".into(), Json::Num(self.net_flushes as f64));
        o.insert("net_coalesced".into(), Json::Num(self.net_coalesced as f64));
        o.insert("mean_coalesced".into(), Json::Num(self.mean_coalesced()));
        o.insert("net_accept_errors".into(), Json::Num(self.net_accept_errors as f64));
        o.insert("net_shed_connections".into(), Json::Num(self.net_shed_connections as f64));
        o.insert("contexts".into(), Json::Num(self.contexts as f64));
        Json::Obj(o)
    }
}

/// One protocol frame.
///
/// | tag | variant | direction | payload |
/// |-----|---------|-----------|---------|
/// | 1 | `Request` | client → server | id u64, model string, context u32, features `[f32]`, trace flag u8 (1 ⇒ + trace_id u64) |
/// | 2 | `Response` | server → client | id u64, class u32, latency_us u64, batch_occupancy u32, worker u32, trace flag u8 (1 ⇒ + trace_id u64, queue_us u32, batch_us u32, execute_us u32) |
/// | 3 | `Error` | server → client | id u64 (0 = connection-level), code u8, message string |
/// | 4 | `HealthRequest` | client → server | empty |
/// | 5 | `HealthReply` | server → client | draining u8, active_connections u32, models `[ModelInfo]` |
/// | 6 | `MetricsRequest` | client → server | model string |
/// | 7 | `MetricsReply` | server → client | [`MetricsSnapshot`] |
/// | 8 | `Shutdown` | both | empty (client: request drain; server: ack) |
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// Classify one feature vector. Responses are matched to requests by
    /// `id` (a connection may pipeline many requests before reading any
    /// response, and responses may arrive out of order).
    Request {
        /// Client-chosen correlation id, echoed in the response.
        id: u64,
        /// Target model (manifest config name).
        model: String,
        /// Target tenant context; must be below the model's advertised
        /// [`ModelInfo::contexts`] (0 = the base context).
        context: u32,
        /// Input feature vector; must match the model's input dimension.
        features: Vec<f32>,
        /// Client-requested trace ID: `Some` asks the server to trace
        /// this request end to end and echo the stage timings in the
        /// response, regardless of the server's sampling rate.
        trace: Option<u64>,
    },
    /// A completed classification.
    Response {
        /// Correlation id of the request this answers.
        id: u64,
        /// Argmax class of the model's logits.
        class: u32,
        /// Server-side submit-to-reply latency in microseconds.
        latency_us: u64,
        /// Live rows in the engine batch that served this request.
        batch_occupancy: u32,
        /// Index of the engine worker that ran the batch.
        worker: u32,
        /// Per-stage timing echo, present when the request was traced
        /// (client-requested or server-sampled).
        trace: Option<TraceEcho>,
    },
    /// A failed request (`id` != 0) or a connection-level fault
    /// (`id` == 0, e.g. an undecodable frame or a connection-cap
    /// rejection).
    Error {
        /// Correlation id of the failed request, 0 for connection-level.
        id: u64,
        /// Machine-readable failure class.
        code: ErrorCode,
        /// Human-readable detail.
        message: String,
    },
    /// Ask the server for its health summary.
    HealthRequest,
    /// Server health: drain state, connection gauge, served models.
    HealthReply {
        /// True once the server has begun drain-then-shutdown.
        draining: bool,
        /// Currently open client connections.
        active_connections: u32,
        /// Shape info for every served model.
        models: Vec<ModelInfo>,
    },
    /// Ask for one model's serving counters.
    MetricsRequest {
        /// Manifest config name.
        model: String,
    },
    /// One model's serving counters.
    MetricsReply(MetricsSnapshot),
    /// Client → server: drain in-flight work and shut down. Server →
    /// client: acknowledgement that the drain has been initiated.
    Shutdown,
}

/// A wire protocol violation or transport failure.
#[derive(Debug)]
pub enum WireError {
    /// The byte stream ended (or the buffer ran out) before the frame
    /// did.
    Truncated,
    /// The first two header bytes are not [`MAGIC`].
    BadMagic,
    /// The header carries a protocol version this build does not speak.
    UnknownVersion(u8),
    /// The header carries a frame type tag this build does not know.
    UnknownType(u8),
    /// The header declares a payload longer than [`MAX_PAYLOAD`]
    /// (the declared length is carried).
    Oversized(usize),
    /// The payload's typed layout is violated (bad UTF-8, out-of-range
    /// count, trailing bytes, unknown error code, ...).
    Malformed(&'static str),
    /// An underlying I/O failure. [`read_frame`] only ever returns a
    /// `WouldBlock`/`TimedOut` I/O error when *zero* bytes of the next
    /// frame have been consumed, so callers using read timeouts may
    /// treat that case as "idle, retry" without losing stream sync.
    Io(std::io::Error),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated => write!(f, "truncated frame"),
            WireError::BadMagic => write!(f, "bad frame magic"),
            WireError::UnknownVersion(v) => write!(f, "unknown protocol version {v}"),
            WireError::UnknownType(t) => write!(f, "unknown frame type {t}"),
            WireError::Oversized(n) => {
                write!(f, "declared payload {n} bytes exceeds cap {MAX_PAYLOAD}")
            }
            WireError::Malformed(what) => write!(f, "malformed payload: {what}"),
            WireError::Io(e) => write!(f, "i/o error: {e}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> Self {
        WireError::Io(e)
    }
}

// ---- encode helpers ------------------------------------------------------

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Strings on the wire are u16-length-prefixed UTF-8.
///
/// # Panics
/// If `s` is 64 KiB or longer (model names and error messages are
/// always far shorter; a length that large is a caller bug).
// length fits u16: asserted on the line above the cast
#[allow(clippy::cast_possible_truncation)]
fn put_str(out: &mut Vec<u8>, s: &str) {
    assert!(s.len() <= u16::MAX as usize, "wire string too long");
    put_u16(out, s.len() as u16);
    out.extend_from_slice(s.as_bytes());
}

// count fits u32: a vector anywhere near 2^32 f32s (16 GiB) would blow
// the MAX_PAYLOAD assert in encode()/encode_request long before the cast
// could wrap
#[allow(clippy::cast_possible_truncation)]
fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    put_u32(out, xs.len() as u32);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

// ---- decode helpers ------------------------------------------------------

/// Bounds-checked reader over a payload slice. Every accessor returns
/// `Err(Malformed)` instead of panicking when the payload runs short.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Cursor<'a> {
        Cursor { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.remaining() < n {
            return Err(WireError::Malformed("payload shorter than its fields"));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, WireError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32, WireError> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn string(&mut self) -> Result<String, WireError> {
        let len = self.u16()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| WireError::Malformed("string is not UTF-8"))
    }

    /// A u32-count-prefixed f32 vector. The count is validated against
    /// the bytes actually present *before* any allocation, so a
    /// corrupted count cannot trigger a huge `Vec` reservation.
    fn f32s(&mut self) -> Result<Vec<f32>, WireError> {
        let n = self.u32()? as usize;
        if n > self.remaining() / 4 {
            return Err(WireError::Malformed("f32 vector count exceeds payload"));
        }
        let mut xs = Vec::with_capacity(n);
        for _ in 0..n {
            xs.push(self.f32()?);
        }
        Ok(xs)
    }

    fn finish(self) -> Result<(), WireError> {
        if self.remaining() != 0 {
            return Err(WireError::Malformed("trailing bytes after payload"));
        }
        Ok(())
    }
}

impl Frame {
    fn type_tag(&self) -> u8 {
        match self {
            Frame::Request { .. } => T_REQUEST,
            Frame::Response { .. } => T_RESPONSE,
            Frame::Error { .. } => T_ERROR,
            Frame::HealthRequest => T_HEALTH_REQUEST,
            Frame::HealthReply { .. } => T_HEALTH_REPLY,
            Frame::MetricsRequest { .. } => T_METRICS_REQUEST,
            Frame::MetricsReply(_) => T_METRICS_REPLY,
            Frame::Shutdown => T_SHUTDOWN,
        }
    }

    // model count fits u16: asserted immediately above the cast
    #[allow(clippy::cast_possible_truncation)]
    fn encode_payload(&self, out: &mut Vec<u8>) {
        match self {
            Frame::Request { id, model, context, features, trace } => {
                request_payload(out, *id, model, *context, features, *trace);
            }
            Frame::Response { id, class, latency_us, batch_occupancy, worker, trace } => {
                put_u64(out, *id);
                put_u32(out, *class);
                put_u64(out, *latency_us);
                put_u32(out, *batch_occupancy);
                put_u32(out, *worker);
                match trace {
                    None => out.push(0),
                    Some(t) => {
                        out.push(1);
                        put_u64(out, t.trace_id);
                        put_u32(out, t.queue_us);
                        put_u32(out, t.batch_us);
                        put_u32(out, t.execute_us);
                    }
                }
            }
            Frame::Error { id, code, message } => {
                put_u64(out, *id);
                out.push(code.as_u8());
                put_str(out, message);
            }
            Frame::HealthRequest | Frame::Shutdown => {}
            Frame::HealthReply { draining, active_connections, models } => {
                out.push(u8::from(*draining));
                put_u32(out, *active_connections);
                assert!(models.len() <= u16::MAX as usize, "too many models");
                put_u16(out, models.len() as u16);
                for m in models {
                    put_str(out, &m.name);
                    put_u32(out, m.features);
                    put_u32(out, m.classes);
                    put_u32(out, m.batch);
                    put_u32(out, m.contexts);
                }
            }
            Frame::MetricsRequest { model } => {
                put_str(out, model);
            }
            Frame::MetricsReply(s) => {
                put_str(out, &s.model);
                put_u64(out, s.requests);
                put_u64(out, s.rejected);
                put_u64(out, s.batches);
                put_u64(out, s.padded_rows);
                put_u64(out, s.stolen);
                put_u64(out, s.quant_saturations);
                put_u64(out, s.p50_us);
                put_u64(out, s.p95_us);
                put_u64(out, s.p99_us);
                put_f64(out, s.mean_occupancy);
                put_u64(out, s.net_flushes);
                put_u64(out, s.net_coalesced);
                put_u64(out, s.net_accept_errors);
                put_u64(out, s.net_shed_connections);
                put_u64(out, s.contexts);
            }
        }
    }

    /// Serialize this frame (header + payload) into a fresh byte vector.
    ///
    /// # Panics
    /// If the payload would exceed [`MAX_PAYLOAD`] (a single feature
    /// vector that size is a caller bug, not a runtime condition).
    // length fits u32: asserted <= MAX_PAYLOAD on the line above the cast
    #[allow(clippy::cast_possible_truncation)]
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(HEADER_LEN + 32);
        out.extend_from_slice(&MAGIC);
        out.push(VERSION);
        out.push(self.type_tag());
        out.extend_from_slice(&[0u8; 4]); // length, patched below
        self.encode_payload(&mut out);
        let len = out.len() - HEADER_LEN;
        assert!(len <= MAX_PAYLOAD, "frame payload exceeds MAX_PAYLOAD");
        out[4..8].copy_from_slice(&(len as u32).to_le_bytes());
        out
    }

    /// Parse one frame from the front of `buf`. On success returns the
    /// frame and the number of bytes consumed (header + payload).
    /// Strict: see the module docs for the full rejection list. Never
    /// panics on arbitrary input.
    pub fn decode(buf: &[u8]) -> Result<(Frame, usize), WireError> {
        if buf.len() < HEADER_LEN {
            return Err(WireError::Truncated);
        }
        let (ftype, len) = parse_header(buf[..HEADER_LEN].try_into().unwrap())?;
        if buf.len() < HEADER_LEN + len {
            return Err(WireError::Truncated);
        }
        let frame = decode_payload(ftype, &buf[HEADER_LEN..HEADER_LEN + len])?;
        Ok((frame, HEADER_LEN + len))
    }
}

/// The `Request` payload layout, shared by [`Frame::encode`] and
/// [`encode_request`] so the two can never diverge.
fn request_payload(
    out: &mut Vec<u8>,
    id: u64,
    model: &str,
    context: u32,
    features: &[f32],
    trace: Option<u64>,
) {
    put_u64(out, id);
    put_str(out, model);
    put_u32(out, context);
    put_f32s(out, features);
    match trace {
        None => out.push(0),
        Some(t) => {
            out.push(1);
            put_u64(out, t);
        }
    }
}

// length fits u32: asserted <= MAX_PAYLOAD on the line above the cast
#[allow(clippy::cast_possible_truncation)]
fn encode_request_with(
    id: u64,
    model: &str,
    context: u32,
    features: &[f32],
    trace: Option<u64>,
) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_LEN + 27 + model.len() + 4 * features.len());
    out.extend_from_slice(&MAGIC);
    out.push(VERSION);
    out.push(T_REQUEST);
    out.extend_from_slice(&[0u8; 4]);
    request_payload(&mut out, id, model, context, features, trace);
    let len = out.len() - HEADER_LEN;
    assert!(len <= MAX_PAYLOAD, "frame payload exceeds MAX_PAYLOAD");
    out[4..8].copy_from_slice(&(len as u32).to_le_bytes());
    out
}

/// Encode a complete untraced `Request` frame from borrowed data —
/// bit-identical to `Frame::Request { trace: None, .. }.encode()` (a
/// unit test pins it) but without cloning the feature vector into a
/// `Frame` first. This is the hot path of
/// [`crate::net::NetClient::classify_pipelined`].
pub fn encode_request(id: u64, model: &str, context: u32, features: &[f32]) -> Vec<u8> {
    encode_request_with(id, model, context, features, None)
}

/// Encode a `Request` frame carrying a client-chosen trace ID — the
/// traced twin of [`encode_request`], bit-identical to
/// `Frame::Request { trace: Some(trace_id), .. }.encode()`.
pub fn encode_request_traced(
    id: u64,
    model: &str,
    context: u32,
    features: &[f32],
    trace_id: u64,
) -> Vec<u8> {
    encode_request_with(id, model, context, features, Some(trace_id))
}

/// Validate a raw header; returns the frame type tag and payload length.
fn parse_header(h: &[u8; HEADER_LEN]) -> Result<(u8, usize), WireError> {
    if h[0..2] != MAGIC {
        return Err(WireError::BadMagic);
    }
    if h[2] != VERSION {
        return Err(WireError::UnknownVersion(h[2]));
    }
    let len = u32::from_le_bytes(h[4..8].try_into().unwrap()) as usize;
    if len > MAX_PAYLOAD {
        return Err(WireError::Oversized(len));
    }
    Ok((h[3], len))
}

/// Decode a complete payload of the given type. Every byte must be
/// consumed.
fn decode_payload(ftype: u8, payload: &[u8]) -> Result<Frame, WireError> {
    let mut c = Cursor::new(payload);
    let frame = match ftype {
        T_REQUEST => {
            let id = c.u64()?;
            let model = c.string()?;
            let context = c.u32()?;
            let features = c.f32s()?;
            let trace = match c.u8()? {
                0 => None,
                1 => Some(c.u64()?),
                _ => return Err(WireError::Malformed("request trace flag not 0/1")),
            };
            Frame::Request { id, model, context, features, trace }
        }
        T_RESPONSE => {
            let id = c.u64()?;
            let class = c.u32()?;
            let latency_us = c.u64()?;
            let batch_occupancy = c.u32()?;
            let worker = c.u32()?;
            let trace = match c.u8()? {
                0 => None,
                1 => Some(TraceEcho {
                    trace_id: c.u64()?,
                    queue_us: c.u32()?,
                    batch_us: c.u32()?,
                    execute_us: c.u32()?,
                }),
                _ => return Err(WireError::Malformed("response trace flag not 0/1")),
            };
            Frame::Response { id, class, latency_us, batch_occupancy, worker, trace }
        }
        T_ERROR => Frame::Error {
            id: c.u64()?,
            code: ErrorCode::from_u8(c.u8()?)
                .ok_or_else(|| WireError::Malformed("unknown error code"))?,
            message: c.string()?,
        },
        T_HEALTH_REQUEST => Frame::HealthRequest,
        T_HEALTH_REPLY => {
            let draining = match c.u8()? {
                0 => false,
                1 => true,
                _ => return Err(WireError::Malformed("draining flag not 0/1")),
            };
            let active_connections = c.u32()?;
            let n = c.u16()? as usize;
            let mut models = Vec::new();
            for _ in 0..n {
                models.push(ModelInfo {
                    name: c.string()?,
                    features: c.u32()?,
                    classes: c.u32()?,
                    batch: c.u32()?,
                    contexts: c.u32()?,
                });
            }
            Frame::HealthReply { draining, active_connections, models }
        }
        T_METRICS_REQUEST => Frame::MetricsRequest { model: c.string()? },
        T_METRICS_REPLY => Frame::MetricsReply(MetricsSnapshot {
            model: c.string()?,
            requests: c.u64()?,
            rejected: c.u64()?,
            batches: c.u64()?,
            padded_rows: c.u64()?,
            stolen: c.u64()?,
            quant_saturations: c.u64()?,
            p50_us: c.u64()?,
            p95_us: c.u64()?,
            p99_us: c.u64()?,
            mean_occupancy: c.f64()?,
            net_flushes: c.u64()?,
            net_coalesced: c.u64()?,
            net_accept_errors: c.u64()?,
            net_shed_connections: c.u64()?,
            contexts: c.u64()?,
        }),
        T_SHUTDOWN => Frame::Shutdown,
        other => return Err(WireError::UnknownType(other)),
    };
    c.finish()?;
    Ok(frame)
}

/// Write one frame to a stream (a single `write_all` of the encoded
/// bytes, so frames from different threads sharing a locked writer never
/// interleave).
pub fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> std::io::Result<()> {
    w.write_all(&frame.encode())
}

/// Read one frame from a stream. `Ok(None)` means the peer closed the
/// connection cleanly *between* frames; a close mid-frame is
/// [`WireError::Truncated`].
///
/// Timeout discipline (see [`WireError::Io`]): a `WouldBlock`/`TimedOut`
/// read error is surfaced to the caller only when zero bytes of the next
/// frame have been consumed — safe to retry. Mid-frame timeouts are
/// retried internally up to a small bound, then reported as
/// [`WireError::Truncated`].
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Frame>, WireError> {
    let mut header = [0u8; HEADER_LEN];
    match read_full(r, &mut header, true)? {
        ReadOutcome::CleanEof => return Ok(None),
        ReadOutcome::Done => {}
    }
    let (ftype, len) = parse_header(&header)?;
    let mut payload = vec![0u8; len];
    match read_full(r, &mut payload, false)? {
        ReadOutcome::CleanEof => unreachable!("CleanEof only at frame start"),
        ReadOutcome::Done => {}
    }
    decode_payload(ftype, &payload).map(Some)
}

enum ReadOutcome {
    /// EOF before the first byte (only possible with `at_frame_start`).
    CleanEof,
    /// Buffer completely filled.
    Done,
}

/// Fill `buf` completely. At a frame boundary (`at_frame_start`), EOF
/// and timeouts before the first byte are non-errors (clean close /
/// idle); once any byte has been consumed, EOF is [`WireError::Truncated`]
/// and timeouts are retried up to [`MID_FRAME_STALL_LIMIT`].
fn read_full<R: Read>(
    r: &mut R,
    buf: &mut [u8],
    at_frame_start: bool,
) -> Result<ReadOutcome, WireError> {
    if buf.is_empty() {
        return Ok(ReadOutcome::Done);
    }
    let mut filled = 0usize;
    let mut stalls = 0usize;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                if filled == 0 && at_frame_start {
                    return Ok(ReadOutcome::CleanEof);
                }
                return Err(WireError::Truncated);
            }
            Ok(n) => {
                filled += n;
                // progress resets the stall budget: the limit is on
                // *consecutive* timeouts, a slow-but-moving peer is fine
                stalls = 0;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if filled == 0 && at_frame_start {
                    // nothing consumed: the caller may treat this as
                    // "idle" and retry without losing stream sync
                    return Err(WireError::Io(e));
                }
                stalls += 1;
                if stalls > MID_FRAME_STALL_LIMIT {
                    return Err(WireError::Truncated);
                }
            }
            Err(e) => return Err(WireError::Io(e)),
        }
    }
    Ok(ReadOutcome::Done)
}

#[cfg(test)]
// test fixtures cast freely between numeric types on hand-picked values
#[allow(clippy::cast_possible_truncation)]
mod tests {
    use super::*;

    fn sample_frames() -> Vec<Frame> {
        vec![
            Frame::Request {
                id: 7,
                model: "tiny".into(),
                context: 2,
                features: vec![0.5, -1.25, 3.0],
                trace: None,
            },
            Frame::Request {
                id: 8,
                model: "tiny".into(),
                context: 0,
                features: vec![1.0],
                trace: Some(0xABCD_EF01),
            },
            Frame::Response {
                id: 7,
                class: 3,
                latency_us: 1234,
                batch_occupancy: 5,
                worker: 1,
                trace: None,
            },
            Frame::Response {
                id: 8,
                class: 0,
                latency_us: 900,
                batch_occupancy: 2,
                worker: 0,
                trace: Some(TraceEcho {
                    trace_id: 0xABCD_EF01,
                    queue_us: 120,
                    batch_us: 340,
                    execute_us: 560,
                }),
            },
            Frame::Error {
                id: 9,
                code: ErrorCode::Busy,
                message: "all shards full".into(),
            },
            Frame::HealthRequest,
            Frame::HealthReply {
                draining: false,
                active_connections: 2,
                models: vec![ModelInfo {
                    name: "tiny".into(),
                    features: 32,
                    classes: 8,
                    batch: 16,
                    contexts: 4,
                }],
            },
            Frame::MetricsRequest { model: "tiny".into() },
            Frame::MetricsReply(MetricsSnapshot {
                model: "tiny".into(),
                requests: 100,
                rejected: 1,
                batches: 20,
                padded_rows: 3,
                stolen: 2,
                quant_saturations: 0,
                p50_us: 128,
                p95_us: 512,
                p99_us: 1024,
                mean_occupancy: 5.0,
                net_flushes: 12,
                net_coalesced: 60,
                net_accept_errors: 1,
                net_shed_connections: 3,
                contexts: 4,
            }),
            Frame::Shutdown,
        ]
    }

    #[test]
    fn roundtrip_every_frame_type() {
        for f in sample_frames() {
            let bytes = f.encode();
            let (back, used) = Frame::decode(&bytes).unwrap();
            assert_eq!(back, f);
            assert_eq!(used, bytes.len());
        }
    }

    #[test]
    fn header_rejections() {
        let good = Frame::HealthRequest.encode();
        // bad magic
        let mut b = good.clone();
        b[0] = b'X';
        assert!(matches!(Frame::decode(&b), Err(WireError::BadMagic)));
        // unknown version
        let mut b = good.clone();
        b[2] = 99;
        assert!(matches!(Frame::decode(&b), Err(WireError::UnknownVersion(99))));
        // unknown type
        let mut b = good.clone();
        b[3] = 200;
        assert!(matches!(Frame::decode(&b), Err(WireError::UnknownType(200))));
        // oversized declared length
        let mut b = good.clone();
        b[4..8].copy_from_slice(&((MAX_PAYLOAD as u32) + 1).to_le_bytes());
        assert!(matches!(Frame::decode(&b), Err(WireError::Oversized(_))));
    }

    #[test]
    fn every_strict_prefix_is_truncated() {
        let bytes = Frame::Request {
            id: 1,
            model: "m".into(),
            context: 0,
            features: vec![1.0, 2.0],
            trace: Some(9),
        }
        .encode();
        for cut in 0..bytes.len() {
            assert!(
                matches!(Frame::decode(&bytes[..cut]), Err(WireError::Truncated)),
                "prefix of {cut} bytes must be Truncated"
            );
        }
    }

    #[test]
    fn trailing_bytes_are_rejected() {
        let mut bytes = Frame::HealthRequest.encode();
        // grow the declared payload without giving it meaning
        bytes.push(0);
        bytes[4..8].copy_from_slice(&1u32.to_le_bytes());
        assert!(matches!(Frame::decode(&bytes), Err(WireError::Malformed(_))));
    }

    #[test]
    fn feature_count_is_validated_before_allocation() {
        // a Request whose declared f32 count vastly exceeds the payload
        let mut bytes = Frame::Request {
            id: 1,
            model: "m".into(),
            context: 0,
            features: vec![],
            trace: None,
        }
        .encode();
        // the f32 count sits just before the trailing trace flag byte
        let n = bytes.len();
        bytes[n - 5..n - 1].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(Frame::decode(&bytes), Err(WireError::Malformed(_))));
    }

    #[test]
    fn trace_flag_must_be_0_or_1() {
        let mut bytes = Frame::Request {
            id: 1,
            model: "m".into(),
            context: 0,
            features: vec![],
            trace: None,
        }
        .encode();
        let n = bytes.len();
        bytes[n - 1] = 2;
        assert!(matches!(Frame::decode(&bytes), Err(WireError::Malformed(_))));
    }

    #[test]
    fn encode_request_matches_frame_encode() {
        let (id, model, context, features) = (42u64, "tiny", 3u32, vec![0.5f32, -2.0, 3.25]);
        assert_eq!(
            encode_request(id, model, context, &features),
            Frame::Request {
                id,
                model: model.to_string(),
                context,
                features: features.clone(),
                trace: None,
            }
            .encode()
        );
        assert_eq!(
            encode_request_traced(id, model, context, &features, 77),
            Frame::Request {
                id,
                model: model.to_string(),
                context,
                features,
                trace: Some(77),
            }
            .encode()
        );
    }

    #[test]
    fn v3_frames_are_version_rejected() {
        // a v3 build writes version byte 3; this build must reject it
        // with UnknownVersion(3), never attempt a cross-version decode
        let mut bytes = Frame::HealthRequest.encode();
        bytes[2] = 3;
        assert!(matches!(Frame::decode(&bytes), Err(WireError::UnknownVersion(3))));
    }

    #[test]
    fn io_roundtrip_and_clean_eof() {
        let mut buf = Vec::new();
        for f in sample_frames() {
            write_frame(&mut buf, &f).unwrap();
        }
        let mut r = std::io::Cursor::new(buf);
        for f in sample_frames() {
            assert_eq!(read_frame(&mut r).unwrap(), Some(f));
        }
        assert_eq!(read_frame(&mut r).unwrap(), None, "clean EOF between frames");
    }

    #[test]
    fn metrics_snapshot_to_json_has_every_field() {
        let Some(Frame::MetricsReply(s)) = sample_frames()
            .into_iter()
            .find(|f| matches!(f, Frame::MetricsReply(_)))
        else {
            unreachable!("sample_frames always contains a MetricsReply")
        };
        let doc = Json::parse(&s.to_json().to_string()).unwrap();
        for key in [
            "requests", "rejected", "batches", "padded_rows", "stolen",
            "quant_saturations", "p50_us", "p95_us", "p99_us", "mean_occupancy",
            "net_flushes", "net_coalesced", "mean_coalesced", "net_accept_errors",
            "net_shed_connections", "contexts",
        ] {
            assert!(doc.get(key).is_some(), "missing {key}");
        }
        assert_eq!(doc.get("model").unwrap().as_str(), Some("tiny"));
        assert_eq!(doc.get("requests").unwrap().as_usize(), Some(100));
        assert_eq!(doc.get("mean_coalesced").unwrap().as_f64(), Some(5.0));
    }

    #[test]
    fn io_eof_mid_frame_is_truncated() {
        let bytes = Frame::MetricsRequest { model: "tiny".into() }.encode();
        let mut r = std::io::Cursor::new(bytes[..bytes.len() - 1].to_vec());
        assert!(matches!(read_frame(&mut r), Err(WireError::Truncated)));
    }
}
