//! Per-connection state machine for the reactor front-end.
//!
//! Each accepted socket becomes one [`Conn`]: a nonblocking
//! `TcpStream`, an incremental read buffer parsed with the strict
//! [`Frame::decode`](super::wire::Frame::decode) slice decoder (which
//! reports `Truncated` for an incomplete frame — exactly the signal an
//! incremental parser needs), and a write side fed from a shared
//! [`Outbox`].
//!
//! The outbox is the only cross-thread surface: batcher completion
//! threads append encoded response frames to it (then wake the
//! reactor), while the reactor alone reads the socket, parses frames,
//! and drains the outbox into the kernel when the socket is writable.
//! A bounded outbox ([`OUTBOX_CAP`]) protects the server from a peer
//! that pipelines requests but never reads responses: once the cap is
//! hit the outbox goes dead and the reactor closes the connection.
//!
//! Fairness: one readiness event lets a connection read at most
//! [`READ_BUDGET`] bytes before the reactor moves on, so a single
//! fire-hose peer cannot starve thousands of idle neighbours on a
//! level-triggered poller (the remaining bytes re-report readable on
//! the next poll).

use std::io::{self, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::AtomicUsize;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::poll::Interest;
use super::wire::{Frame, WireError};
use crate::util::sync::lock_unpoisoned;

/// Most bytes one connection may read per readiness event before the
/// reactor moves to the next connection (fairness bound).
pub const READ_BUDGET: usize = 64 * 1024;

/// Upper bound on queued-but-unflushed response bytes per connection;
/// beyond this the peer is evidently not reading and the outbox goes
/// dead (the reactor then closes the connection).
pub const OUTBOX_CAP: usize = 4 << 20;

/// Cross-thread response queue: batcher completion threads push encoded
/// frames, the reactor drains them into the socket.
pub struct Outbox {
    inner: Mutex<OutboxInner>,
}

struct OutboxInner {
    buf: Vec<u8>,
    dead: bool,
}

impl Outbox {
    /// Empty, live outbox.
    pub fn new() -> Outbox {
        Outbox { inner: Mutex::new(OutboxInner { buf: Vec::new(), dead: false }) }
    }

    /// Append one encoded frame. Returns `false` (and marks the outbox
    /// dead) if the connection is already dead or the cap would be
    /// exceeded — the caller should drop the response and not count it.
    pub fn push(&self, bytes: &[u8]) -> bool {
        let mut g = lock_unpoisoned(&self.inner);
        if g.dead {
            return false;
        }
        if g.buf.len() + bytes.len() > OUTBOX_CAP {
            g.dead = true;
            return false;
        }
        g.buf.extend_from_slice(bytes);
        true
    }

    /// Move all queued bytes into `into` (appending), leaving the
    /// outbox empty. Reactor-side only.
    pub fn take(&self, into: &mut Vec<u8>) {
        let mut g = lock_unpoisoned(&self.inner);
        if !g.buf.is_empty() {
            into.extend_from_slice(&g.buf);
            g.buf.clear();
        }
    }

    /// True when no bytes are queued.
    pub fn is_empty(&self) -> bool {
        lock_unpoisoned(&self.inner).buf.is_empty()
    }

    /// Mark the connection dead: every later [`push`](Outbox::push)
    /// returns `false` without queueing.
    pub fn mark_dead(&self) {
        lock_unpoisoned(&self.inner).dead = true;
    }

    /// True once [`mark_dead`](Outbox::mark_dead) ran or the cap blew.
    pub fn is_dead(&self) -> bool {
        lock_unpoisoned(&self.inner).dead
    }
}

impl Default for Outbox {
    fn default() -> Self {
        Outbox::new()
    }
}

/// Lifecycle of a connection inside the reactor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConnState {
    /// Parsing request frames and serving them.
    Open,
    /// No longer parsing: flush pending output, absorb (and discard)
    /// any bytes the peer is still sending so the final close is an
    /// orderly FIN rather than a RST that could destroy an unread
    /// error frame, then close on flushed-EOF or linger expiry.
    Closing,
}

/// What a read pass observed.
#[derive(Clone, Copy, Debug)]
pub struct FillOutcome {
    /// Bytes appended to the parse buffer this pass.
    pub bytes: usize,
    /// Peer closed its write side (observed EOF).
    pub eof: bool,
    /// Hard socket error — the connection is unusable.
    pub gone: bool,
    /// Stopped because [`READ_BUDGET`] was spent; more data may be
    /// pending and the poller will re-report readable.
    pub budget_spent: bool,
}

/// What a flush pass achieved.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlushOutcome {
    /// Everything queued (write buffer and outbox) hit the kernel.
    Flushed,
    /// The kernel buffer filled; register write interest and retry on
    /// writability.
    Blocked,
    /// Hard socket error — the connection is unusable.
    Gone,
}

/// One reactor-owned connection: socket, incremental parse buffer,
/// write-side staging, and the deadlines that bound misbehaving peers.
pub struct Conn {
    /// The nonblocking socket (reactor-owned; never cloned).
    pub stream: TcpStream,
    /// Shared response queue (cloned into batcher responders).
    pub outbox: Arc<Outbox>,
    /// Responses enqueued to the batcher but not yet resolved; the
    /// drain path waits for this to reach zero before closing.
    pub in_flight: Arc<AtomicUsize>,
    /// Lifecycle state.
    pub state: ConnState,
    /// Armed while a partial frame sits in the parse buffer: the
    /// instant by which the frame must complete (slow-loris guard).
    pub frame_deadline: Option<Instant>,
    /// Armed in [`ConnState::Closing`]: force-close at this instant
    /// even if output is unflushed or the peer never EOFs.
    pub linger_deadline: Option<Instant>,
    /// Peer EOF observed (write side of the peer closed).
    pub peer_eof: bool,
    /// Whether this connection occupies an admitted slot (false for
    /// over-cap courtesy-Busy sheds, which are bounded separately).
    pub counted: bool,
    /// Interest currently registered with the poller (the reactor
    /// reregisters when the desired set diverges).
    pub interest: Interest,
    rbuf: Vec<u8>,
    rpos: usize,
    wbuf: Vec<u8>,
    wpos: usize,
}

impl Conn {
    /// Wrap an accepted, already-nonblocking socket.
    pub fn new(stream: TcpStream, counted: bool) -> Conn {
        Conn {
            stream,
            outbox: Arc::new(Outbox::new()),
            in_flight: Arc::new(AtomicUsize::new(0)),
            state: ConnState::Open,
            frame_deadline: None,
            linger_deadline: None,
            peer_eof: false,
            counted,
            interest: Interest::READ,
            rbuf: Vec::new(),
            rpos: 0,
            wbuf: Vec::new(),
            wpos: 0,
        }
    }

    /// Read up to [`READ_BUDGET`] bytes into the parse buffer. Sets
    /// [`peer_eof`](Conn::peer_eof) when EOF is observed.
    pub fn fill(&mut self) -> FillOutcome {
        let mut out = FillOutcome { bytes: 0, eof: false, gone: false, budget_spent: false };
        let mut chunk = [0u8; 4096];
        while out.bytes < READ_BUDGET {
            match self.stream.read(&mut chunk) {
                Ok(0) => {
                    self.peer_eof = true;
                    out.eof = true;
                    return out;
                }
                Ok(n) => {
                    self.rbuf.extend_from_slice(&chunk[..n]);
                    out.bytes += n;
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return out,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(_) => {
                    out.gone = true;
                    return out;
                }
            }
        }
        out.budget_spent = true;
        out
    }

    /// Try to parse the next complete frame from the buffer. `None`
    /// means "need more bytes" (the consumed prefix is compacted away);
    /// a decode error is terminal for the connection.
    pub fn next_frame(&mut self) -> Option<Result<Frame, WireError>> {
        if self.rpos == self.rbuf.len() {
            self.rbuf.clear();
            self.rpos = 0;
        }
        match Frame::decode(&self.rbuf[self.rpos..]) {
            Ok((frame, used)) => {
                self.rpos += used;
                Some(Ok(frame))
            }
            Err(WireError::Truncated) => {
                if self.rpos > 0 {
                    self.rbuf.drain(..self.rpos);
                    self.rpos = 0;
                }
                None
            }
            Err(e) => Some(Err(e)),
        }
    }

    /// True while an incomplete frame sits in the parse buffer — the
    /// condition that arms the slow-loris frame deadline.
    pub fn has_partial(&self) -> bool {
        self.rbuf.len() > self.rpos
    }

    /// Drop all buffered input (entering [`ConnState::Closing`]).
    pub fn discard_input(&mut self) {
        self.rbuf.clear();
        self.rpos = 0;
    }

    /// Flush staged bytes then the outbox into the socket until done or
    /// the kernel buffer blocks.
    pub fn flush(&mut self) -> FlushOutcome {
        loop {
            if self.wpos == self.wbuf.len() {
                self.wbuf.clear();
                self.wpos = 0;
                self.outbox.take(&mut self.wbuf);
                if self.wbuf.is_empty() {
                    return FlushOutcome::Flushed;
                }
            }
            match self.stream.write(&self.wbuf[self.wpos..]) {
                Ok(0) => return FlushOutcome::Gone,
                Ok(n) => self.wpos += n,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return FlushOutcome::Blocked,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(_) => {
                    self.outbox.mark_dead();
                    return FlushOutcome::Gone;
                }
            }
        }
    }

    /// True while bytes wait in the staging buffer or the outbox.
    pub fn has_pending_output(&self) -> bool {
        self.wpos < self.wbuf.len() || !self.outbox.is_empty()
    }

    /// The interest set this connection wants right now: always read
    /// (Open parses, Closing absorbs-and-discards so the final close is
    /// orderly), plus write only while output is queued.
    pub fn desired_interest(&self) -> Interest {
        Interest { read: !self.peer_eof, write: self.has_pending_output() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    fn conn_pair() -> (Conn, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let peer = TcpStream::connect(addr).unwrap();
        let (srv, _) = listener.accept().unwrap();
        srv.set_nonblocking(true).unwrap();
        (Conn::new(srv, true), peer)
    }

    #[test]
    fn parses_a_frame_dribbled_byte_by_byte() {
        let (mut conn, mut peer) = conn_pair();
        let frame = Frame::Request {
            id: 42,
            model: "tiny".into(),
            context: 1,
            features: vec![0.5, -0.25],
            trace: Some(7),
        };
        let bytes = frame.encode();
        for (i, b) in bytes.iter().enumerate() {
            peer.write_all(std::slice::from_ref(b)).unwrap();
            peer.flush().unwrap();
            // wait for the byte to land, then parse
            let deadline = Instant::now() + std::time::Duration::from_secs(5);
            loop {
                let f = conn.fill();
                assert!(!f.gone && !f.eof);
                if f.bytes > 0 {
                    break;
                }
                assert!(Instant::now() < deadline, "byte never arrived");
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            if i + 1 < bytes.len() {
                assert!(conn.next_frame().is_none(), "frame complete too early");
                assert!(conn.has_partial());
            }
        }
        match conn.next_frame() {
            Some(Ok(Frame::Request { id, model, context, features, trace })) => {
                assert_eq!(id, 42);
                assert_eq!(model, "tiny");
                assert_eq!(context, 1);
                assert_eq!(features, vec![0.5, -0.25]);
                assert_eq!(trace, Some(7));
            }
            other => panic!("expected parsed request, got {other:?}"),
        }
        assert!(!conn.has_partial());
    }

    #[test]
    fn parses_back_to_back_frames_from_one_fill() {
        let (mut conn, mut peer) = conn_pair();
        let mut bytes = Frame::HealthRequest.encode();
        bytes.extend_from_slice(&Frame::Shutdown.encode());
        peer.write_all(&bytes).unwrap();
        peer.flush().unwrap();
        let deadline = Instant::now() + std::time::Duration::from_secs(5);
        let mut got = 0usize;
        while got < bytes.len() {
            let f = conn.fill();
            got += f.bytes;
            assert!(Instant::now() < deadline, "bytes never arrived");
        }
        assert!(matches!(conn.next_frame(), Some(Ok(Frame::HealthRequest))));
        assert!(matches!(conn.next_frame(), Some(Ok(Frame::Shutdown))));
        assert!(conn.next_frame().is_none());
    }

    #[test]
    fn outbox_cap_marks_dead_instead_of_growing() {
        let outbox = Outbox::new();
        let chunk = vec![0u8; OUTBOX_CAP / 2];
        assert!(outbox.push(&chunk));
        assert!(outbox.push(&chunk)); // exactly at the cap is still fine
        // one more byte would exceed the cap
        assert!(!outbox.push(&[0u8; 1]));
        assert!(outbox.is_dead());
        assert!(!outbox.push(b"x"), "dead outbox refuses everything");
    }

    #[test]
    fn flush_delivers_outbox_bytes_to_the_peer() {
        let (mut conn, mut peer) = conn_pair();
        let payload = Frame::HealthRequest.encode();
        assert!(conn.outbox.push(&payload));
        assert!(conn.has_pending_output());
        assert_eq!(conn.flush(), FlushOutcome::Flushed);
        assert!(!conn.has_pending_output());
        let mut got = vec![0u8; payload.len()];
        peer.read_exact(&mut got).unwrap();
        assert_eq!(got, payload);
    }

    #[test]
    fn eof_is_reported_once_peer_closes() {
        let (mut conn, peer) = conn_pair();
        drop(peer);
        let deadline = Instant::now() + std::time::Duration::from_secs(5);
        loop {
            let f = conn.fill();
            if f.eof {
                break;
            }
            assert!(!f.gone);
            assert!(Instant::now() < deadline, "EOF never observed");
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert!(conn.peer_eof);
        assert!(!conn.desired_interest().read, "no read interest after EOF");
    }
}
