//! Reactor TCP front-end over the in-process [`InferenceService`].
//!
//! [`NetServer`] is the network boundary the rest of the crate never
//! had: a single readiness-driven event loop (no tokio — the design
//! note in [`crate::coordinator::server`] applies: offline build,
//! compute-bound request path) that speaks the [`crate::net::wire`]
//! protocol and feeds every `Request` frame through a per-model
//! [`MicroBatcher`](crate::net::MicroBatcher) so concurrent socket
//! traffic reaches the engine as coalesced batches.
//!
//! - **One reactor thread, thousands of connections.** Every accepted
//!   socket is nonblocking and registered with a [`crate::net::poll`]
//!   poller; a [`Conn`](crate::net::conn::Conn) state machine parses
//!   frames incrementally with the strict slice decoder and stages
//!   responses in a shared outbox. An idle connection costs one poll
//!   slot — no thread, no stack, no 100 ms sleep-poll tick.
//! - **Waker path.** Batcher completion threads never touch sockets:
//!   a responder encodes the `Response`/`Error` frame into the
//!   connection's outbox, marks the connection dirty, and wakes the
//!   reactor through a coalescing self-pipe
//!   ([`crate::net::poll::Waker`]). The reactor flushes the outbox
//!   when the socket is writable, preserving pipelining-by-frame-id.
//! - **Fairness.** A readiness event lets one connection read at most
//!   [`crate::net::conn::READ_BUDGET`] bytes before the loop moves on;
//!   a fire-hose peer re-reports readable on the next poll instead of
//!   starving its neighbours.
//! - **Connection cap.** Beyond [`NetServerConfig::max_connections`]
//!   live connections, a new peer receives one `Error{Busy}` frame and
//!   a lingering close — explicit shed, mirroring the engine's bounded
//!   shards. Courtesy sheds are themselves bounded; past that bound a
//!   flood is dropped without the frame.
//! - **Misbehaving peers are bounded, not trusted.** A partial frame
//!   must complete within [`ReactorTuning::frame_timeout`] (slow-loris
//!   guard); a peer that never reads its responses trips the outbox
//!   cap; both end in an error frame and a lingering close.
//! - **Graceful drain-then-shutdown.** [`NetServer::shutdown`] stops
//!   accepting, answers every admitted request (batchers flush partial
//!   groups immediately), flushes every outbox, then joins the reactor
//!   and batcher threads. A client can request the same drain remotely
//!   with a `Shutdown` frame — [`NetServer::run_until_shutdown`]
//!   blocks until one arrives.
//! - **Strict decode.** An undecodable frame gets one best-effort
//!   `Error{BadRequest}` frame and the connection is closed; the
//!   server never guesses at resynchronization.

use std::collections::{BTreeMap, BTreeSet};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::batcher::{BatchItem, BatcherConfig, BatcherHandle, MicroBatcher};
use super::conn::{Conn, ConnState, FlushOutcome, Outbox};
use super::poll::{
    self, new_poller, source, Interest, PollEvent, Poller, Token, WakeReceiver, Waker,
};
use super::wire::{ErrorCode, Frame, MetricsSnapshot, ModelInfo, WireError};
use crate::coordinator::{InferenceService, ServeError};
use crate::obs::registry::Sample;
use crate::obs::trace::{ReqTrace, Sampler, TraceSink};
use crate::util::sync::{lock_unpoisoned, wait_timeout_unpoisoned};

/// Poll-set token of the listening socket.
const TOKEN_LISTENER: Token = 0;
/// Poll-set token of the waker's receive side.
const TOKEN_WAKER: Token = 1;
/// First connection token; connection slab index `i` maps to token
/// `i + TOKEN_CONN0`.
const TOKEN_CONN0: Token = 2;
/// How long the listener stays masked after a transient `accept()`
/// error (EMFILE and friends): distinct from the idle path, which
/// costs nothing — an idle listener simply reports no readiness.
const ACCEPT_ERROR_BACKOFF: Duration = Duration::from_millis(50);
/// Reactor sweep cadence while draining, so shutdown progresses even
/// if a wake is lost.
const DRAIN_POLL: Duration = Duration::from_millis(50);
/// Grace period for flushing queued output to a slow peer during
/// drain, or to a peer that half-closed with replies still in flight
/// (the analogue of the old per-write 5 s timeout).
const EOF_WRITE_GRACE: Duration = Duration::from_secs(5);
/// Cap on concurrent courtesy-Busy sheds held in the poll set. Beyond
/// it, over-cap connections are dropped outright — under a connect
/// flood the resource bound matters more than the courtesy frame.
const MAX_SHED_CONNS: usize = 64;

/// Tuning knobs for the TCP front-end.
#[derive(Clone, Copy, Debug)]
pub struct NetServerConfig {
    /// Live-connection cap; peers beyond it are shed with one
    /// `Error{Busy}` frame (CLI: `--max-conns`). Under the reactor
    /// this is a memory/fairness bound, not a thread count — thousands
    /// per reactor thread are practical.
    pub max_connections: usize,
    /// Micro-batcher flush deadline — *the* latency/throughput knob of
    /// the socket path, armed when a group's first request arrives
    /// (CLI: `serve --listen ... --batch-window USEC`; 0 = flush every
    /// request immediately).
    pub batch_window: Duration,
    /// Trace one request in every `trace_sample` (CLI:
    /// `serve --listen ... --trace-sample N`; 0 disables sampling —
    /// the default — leaving only the single-branch sampler check on
    /// the request path). Client-requested traces (a v4 `Request`
    /// carrying a trace ID) are honored regardless of this setting.
    pub trace_sample: u64,
}

impl Default for NetServerConfig {
    fn default() -> Self {
        NetServerConfig {
            max_connections: 1024,
            batch_window: Duration::from_millis(1),
            trace_sample: 0,
        }
    }
}

/// Reactor timing knobs, separate from [`NetServerConfig`] so existing
/// callers keep compiling; [`NetServer::start`] uses the defaults.
#[derive(Clone, Copy, Debug)]
pub struct ReactorTuning {
    /// A partially received frame must complete within this span or
    /// the connection is closed with `Error{BadRequest}` — the
    /// slow-loris bound (CLI: `serve --frame-timeout-ms`).
    pub frame_timeout: Duration,
    /// How long a closing connection lingers to flush its final frame
    /// and absorb peer bytes so the close is an orderly FIN (an RST
    /// could wipe an unread error frame out of the peer's receive
    /// buffer).
    pub linger: Duration,
}

impl Default for ReactorTuning {
    fn default() -> Self {
        ReactorTuning {
            frame_timeout: Duration::from_secs(5),
            linger: Duration::from_millis(250),
        }
    }
}

/// Network-layer counters (the engine layer keeps its own
/// [`crate::coordinator::ModelMetrics`]). All atomics, readable at any
/// time with `Ordering::Relaxed`.
#[derive(Debug, Default)]
pub struct NetMetrics {
    /// Connections accepted and admitted.
    pub accepted: AtomicU64,
    /// Connections shed at the cap with `Error{Busy}`.
    pub rejected_connections: AtomicU64,
    /// Transient `accept()` failures (e.g. EMFILE); each one also
    /// masks the listener for a short distinct backoff.
    pub accept_errors: AtomicU64,
    /// Valid request frames received (including ones the micro-batcher
    /// then shed synchronously with `Busy`/`Stopped`; reconcile against
    /// [`crate::net::BatcherMetrics::rejected`] for admitted-only
    /// counts).
    pub requests: AtomicU64,
    /// Response frames queued for delivery (successful predictions).
    pub responses: AtomicU64,
    /// Error frames queued for delivery (per-request and
    /// connection-level).
    pub errors: AtomicU64,
    /// Connections dropped on an undecodable frame (including partial
    /// frames that blew the slow-loris deadline).
    pub wire_errors: AtomicU64,
    /// Currently open admitted connections (gauge).
    pub active: AtomicUsize,
    /// High-water mark of the `active` gauge over the server's life —
    /// the number the scale-out claim is judged by.
    pub peak_active: AtomicUsize,
}

/// Shared state between the reactor, the batcher responders, and the
/// owner.
struct ServerShared {
    /// The engine service (the reactor reads its metrics for
    /// `MetricsRequest` frames; submissions go through the batchers'
    /// own clients).
    svc: Arc<InferenceService>,
    /// Set by [`NetServer::shutdown`]: stop accepting, drain, exit.
    stop: AtomicBool,
    /// Set when a peer sends a `Shutdown` frame; wakes
    /// [`NetServer::run_until_shutdown`].
    shutdown_requested: Mutex<bool>,
    shutdown_cv: Condvar,
    metrics: NetMetrics,
    /// Mints trace IDs for sampled requests (disabled at `--trace-sample 0`:
    /// one branch per request, nothing else).
    sampler: Sampler,
    /// Collects span events from every sampled request's trace.
    trace_sink: Arc<TraceSink>,
    /// Per-model enqueue handles (immutable after startup).
    batchers: BTreeMap<String, BatcherHandle>,
    /// Wakes the reactor's poll when a responder queues output.
    waker: Waker,
    /// Connection slab indices whose outbox gained frames since the
    /// reactor last flushed (stale entries are harmless: flushing a
    /// reused slot flushes that slot's own outbox).
    dirty: Mutex<Vec<usize>>,
}

impl ServerShared {
    fn health_frame(&self) -> Frame {
        Frame::HealthReply {
            draining: self.stop.load(Ordering::Acquire),
            active_connections: self.metrics.active.load(Ordering::Relaxed) as u32,
            models: self
                .batchers
                .values()
                .map(|b| ModelInfo {
                    name: b.model().to_string(),
                    features: b.features() as u32,
                    classes: b.classes() as u32,
                    batch: b.batch() as u32,
                    contexts: b.contexts() as u32,
                })
                .collect(),
        }
    }
}

/// Emit the server-level counters as registry samples (`net.*`, no
/// labels — there is one front door per service).
fn collect_net_samples(shared: &ServerShared, out: &mut Vec<Sample>) {
    let m = &shared.metrics;
    let c = Ordering::Relaxed;
    let no = Vec::new;
    out.push(Sample::counter("net.accepted_connections", no(), m.accepted.load(c)));
    out.push(Sample::counter(
        "net.rejected_connections",
        no(),
        m.rejected_connections.load(c),
    ));
    out.push(Sample::counter("net.accept_errors", no(), m.accept_errors.load(c)));
    out.push(Sample::counter("net.requests", no(), m.requests.load(c)));
    out.push(Sample::counter("net.responses", no(), m.responses.load(c)));
    out.push(Sample::counter("net.errors", no(), m.errors.load(c)));
    out.push(Sample::counter("net.wire_errors", no(), m.wire_errors.load(c)));
    out.push(Sample::gauge("net.active", no(), m.active.load(c) as f64));
    out.push(Sample::gauge("net.peak_active", no(), m.peak_active.load(c) as f64));
    out.push(Sample::counter("net.trace_events", no(), shared.trace_sink.len() as u64));
    out.push(Sample::counter("net.trace_dropped", no(), shared.trace_sink.dropped()));
}

/// Queue one frame into a connection's outbox, counting it in the
/// network metrics iff the outbox accepted it (a dead or over-cap
/// outbox drops the frame). Called from the reactor *and* from batcher
/// responder threads.
fn push_counted(metrics: &NetMetrics, outbox: &Outbox, frame: &Frame) -> bool {
    if !outbox.push(&frame.encode()) {
        return false;
    }
    match frame {
        Frame::Response { .. } => {
            metrics.responses.fetch_add(1, Ordering::Relaxed);
        }
        Frame::Error { .. } => {
            metrics.errors.fetch_add(1, Ordering::Relaxed);
        }
        _ => {}
    }
    true
}

/// The TCP front-end. See the module docs for the architecture.
///
/// Startup takes the service as an `Arc` so the owner can keep an
/// in-process [`crate::coordinator::Client`] to the very same engines —
/// which is how the end-to-end tests prove socket inference bit-identical
/// to in-process inference. [`NetServer::shutdown`] hands the `Arc`
/// back after the network drain, so the owner decides when the engine
/// workers stop.
pub struct NetServer {
    svc: Arc<InferenceService>,
    shared: Arc<ServerShared>,
    reactor: Option<JoinHandle<()>>,
    batchers: Vec<MicroBatcher>,
    addr: SocketAddr,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port), spawn
    /// one micro-batcher per served model and the reactor thread, and
    /// return immediately. The bound address is [`NetServer::local_addr`].
    pub fn start(
        svc: Arc<InferenceService>,
        addr: impl ToSocketAddrs,
        cfg: NetServerConfig,
    ) -> Result<NetServer> {
        Self::start_tuned(svc, addr, cfg, ReactorTuning::default())
    }

    /// [`NetServer::start`] with explicit [`ReactorTuning`] (the e2e
    /// tests shrink the slow-loris deadline this way).
    pub fn start_tuned(
        svc: Arc<InferenceService>,
        addr: impl ToSocketAddrs,
        cfg: NetServerConfig,
        tuning: ReactorTuning,
    ) -> Result<NetServer> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let mut batchers = Vec::new();
        let mut handles = BTreeMap::new();
        for model in svc.models() {
            let client = svc.client(&model)?;
            let bcfg = BatcherConfig::for_client(&client, cfg.batch_window);
            let b = MicroBatcher::start(client, bcfg);
            // batcher counters join the service's registry, so one
            // snapshot covers engine + coalescing + (below) net counters
            b.register_collector(svc.registry());
            handles.insert(model, b.handle());
            batchers.push(b);
        }
        let (waker, wake_rx) = poll::wake_pair()?;
        let shared = Arc::new(ServerShared {
            svc: Arc::clone(&svc),
            stop: AtomicBool::new(false),
            shutdown_requested: Mutex::new(false),
            shutdown_cv: Condvar::new(),
            metrics: NetMetrics::default(),
            sampler: Sampler::new(cfg.trace_sample),
            trace_sink: Arc::new(TraceSink::new(TraceSink::DEFAULT_CAP)),
            batchers: handles,
            waker,
            dirty: Mutex::new(Vec::new()),
        });
        // Weak: the registry (owned by the service, which outlives this
        // server) must not keep the drained server's state alive — the
        // shutdown path hands the service Arc back to the owner
        let weak = Arc::downgrade(&shared);
        svc.registry().register(move |out| {
            if let Some(shared) = weak.upgrade() {
                collect_net_samples(&shared, out);
            }
        });
        let reactor = {
            let shared = Arc::clone(&shared);
            let max_conns = cfg.max_connections.max(1);
            std::thread::Builder::new()
                .name("pds-reactor".to_string())
                .spawn(move || {
                    let mut poller = new_poller();
                    let _ = poller.register(source(&listener), TOKEN_LISTENER, Interest::READ);
                    let _ = poller.register(wake_rx.source(), TOKEN_WAKER, Interest::READ);
                    Reactor {
                        shared,
                        listener,
                        wake_rx,
                        poller,
                        conns: Vec::new(),
                        free: Vec::new(),
                        max_conns,
                        tuning,
                        accept_backoff_until: None,
                        shed_live: 0,
                        deadlined: BTreeSet::new(),
                        draining: false,
                    }
                    .run();
                })?
        };
        Ok(NetServer {
            svc,
            shared,
            reactor: Some(reactor),
            batchers,
            addr,
        })
    }

    /// The address the listener actually bound (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Network-layer counters.
    pub fn metrics(&self) -> &NetMetrics {
        &self.shared.metrics
    }

    /// The span sink sampled request traces record into. Clone the
    /// `Arc` before [`NetServer::shutdown`] to export
    /// [`TraceSink::to_chrome_json`] after the drain (the CLI's
    /// `serve --trace-out PATH` does exactly that).
    pub fn trace_sink(&self) -> &Arc<TraceSink> {
        &self.shared.trace_sink
    }

    /// The served models' metrics snapshot as sent to clients
    /// (engine counters + this server's micro-batcher coalescing +
    /// the server-level accept/shed counters).
    pub fn model_snapshot(&self, model: &str) -> Option<MetricsSnapshot> {
        let mut snap = model_metrics_snapshot(&self.svc, self.shared.batchers.get(model)?)?;
        snap.net_accept_errors = self.shared.metrics.accept_errors.load(Ordering::Relaxed);
        snap.net_shed_connections = self
            .shared
            .metrics
            .rejected_connections
            .load(Ordering::Relaxed);
        Some(snap)
    }

    /// Enqueue handle of `model`'s micro-batcher. The handle stays
    /// valid (for metrics reads) after [`NetServer::shutdown`], which
    /// is how the CLI reports final post-drain coalescing numbers.
    pub fn batcher(&self, model: &str) -> Option<BatcherHandle> {
        self.shared.batchers.get(model).cloned()
    }

    /// Block until a peer requests drain with a `Shutdown` frame (or
    /// [`NetServer::shutdown`] is invoked from another thread). The CLI
    /// parks here between "listening" and the drain.
    pub fn run_until_shutdown(&self) {
        let mut requested = lock_unpoisoned(&self.shared.shutdown_requested);
        while !*requested && !self.shared.stop.load(Ordering::Acquire) {
            let (guard, _) = wait_timeout_unpoisoned(
                &self.shared.shutdown_cv,
                requested,
                Duration::from_millis(200),
            );
            requested = guard;
        }
    }

    /// Drain-then-shutdown of the *network* layer: stop accepting, let
    /// every admitted request finish and its response flush, join the
    /// reactor and every batcher thread — then hand the inference
    /// service back to the owner (who calls
    /// [`InferenceService::shutdown`] once no other `Arc`s remain).
    pub fn shutdown(mut self) -> Result<Arc<InferenceService>> {
        self.shared.stop.store(true, Ordering::Release);
        self.shared.shutdown_cv.notify_all();
        // flush queued partial groups now, so the reactor drain below is
        // bounded by engine execution time, not by the batch window
        for b in &self.batchers {
            b.request_stop();
        }
        // the reactor may be parked in an indefinite poll
        self.shared.waker.wake();
        // a panicked thread is reported, but never short-circuits the
        // teardown: every batcher is still drained before the error
        // surfaces
        let mut first_err: Option<anyhow::Error> = None;
        if let Some(h) = self.reactor.take() {
            if h.join().is_err() {
                first_err = Some(anyhow::anyhow!("reactor thread panicked"));
            }
        }
        // batchers flush partial groups immediately on stop and join
        // their completion threads, so every admitted request has been
        // answered by the time this returns
        for b in self.batchers.drain(..) {
            b.shutdown();
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(Arc::clone(&self.svc)),
        }
    }
}

impl Drop for NetServer {
    /// Dropping without [`NetServer::shutdown`] still signals the
    /// reactor and batchers to stop; they drain detached rather than
    /// joined.
    fn drop(&mut self) {
        self.shared.stop.store(true, Ordering::Release);
        self.shared.shutdown_cv.notify_all();
        self.shared.waker.wake();
    }
}

/// Build the combined engine + micro-batcher metrics snapshot for one
/// model — what a `MetricsReply` frame carries, also usable after
/// [`NetServer::shutdown`] with the returned service and a
/// [`BatcherHandle`] to report final post-drain numbers.
///
/// The server-level counters (`net_accept_errors`,
/// `net_shed_connections`) are not derivable from the service and
/// batcher alone and are left zero here; [`NetServer::model_snapshot`]
/// and the live `MetricsRequest` path fill them in.
pub fn model_metrics_snapshot(
    svc: &InferenceService,
    batcher: &BatcherHandle,
) -> Option<MetricsSnapshot> {
    let model = batcher.model().to_string();
    // one coherent registry snapshot feeds the whole frame: engine
    // counters (registered at service start) and, when this batcher ran
    // under a NetServer, its coalescing counters too. A standalone
    // batcher (tests, post-shutdown reporting without a server) was
    // never registered — fall back to its own atomics for those two.
    let snap = svc.registry().snapshot();
    let labels: &[(&str, &str)] = &[("model", &model)];
    let requests = snap.counter("serve.requests", labels)?;
    let hist = snap.histogram("serve.latency", labels).unwrap_or_default();
    let bm = batcher.metrics();
    Some(MetricsSnapshot {
        contexts: batcher.contexts() as u64,
        requests,
        rejected: snap.counter("serve.rejected", labels).unwrap_or(0),
        batches: snap.counter("serve.batches", labels).unwrap_or(0),
        padded_rows: snap.counter("serve.padded_rows", labels).unwrap_or(0),
        stolen: snap.counter("serve.stolen", labels).unwrap_or(0),
        quant_saturations: snap.counter("serve.quant_saturations", labels).unwrap_or(0),
        p50_us: hist.p50_us,
        p95_us: hist.p95_us,
        p99_us: hist.p99_us,
        mean_occupancy: snap.gauge("serve.occupancy_mean", labels).unwrap_or(0.0),
        net_flushes: snap
            .counter("batcher.flushes", labels)
            .unwrap_or_else(|| bm.flushes.load(Ordering::Relaxed)),
        net_coalesced: snap
            .counter("batcher.coalesced", labels)
            .unwrap_or_else(|| bm.coalesced.load(Ordering::Relaxed)),
        net_accept_errors: 0,
        net_shed_connections: 0,
        model,
    })
}

/// Truncate a client-supplied string before echoing it into an error
/// message: wire strings are capped at u16::MAX bytes and the encoder
/// asserts on longer ones, so echoing a hostile 64 KiB model name
/// verbatim could panic the server. 64 bytes is plenty for diagnosis.
fn shorten(s: &str) -> String {
    const MAX: usize = 64;
    if s.len() <= MAX {
        return s.to_string();
    }
    let mut end = MAX;
    while !s.is_char_boundary(end) {
        end -= 1;
    }
    format!("{}...", &s[..end])
}

/// Map an engine rejection onto a wire error code.
fn code_for(e: ServeError) -> ErrorCode {
    match e {
        ServeError::Busy => ErrorCode::Busy,
        ServeError::Stopped => ErrorCode::Stopped,
    }
}

/// The event loop: one thread owning the listener, the waker's receive
/// side, and every connection. All socket I/O happens here; the only
/// cross-thread traffic is outbox pushes + dirty-token + wake from
/// batcher responders.
struct Reactor {
    shared: Arc<ServerShared>,
    listener: TcpListener,
    wake_rx: WakeReceiver,
    poller: Box<dyn Poller>,
    /// Connection slab; index `i` is poll token `i + TOKEN_CONN0`.
    conns: Vec<Option<Conn>>,
    free: Vec<usize>,
    max_conns: usize,
    tuning: ReactorTuning,
    /// Listener masked until this instant after an accept error.
    accept_backoff_until: Option<Instant>,
    /// Courtesy-Busy sheds currently occupying slab slots.
    shed_live: usize,
    /// Slab indices with an armed frame or linger deadline — the only
    /// connections the timeout scan has to visit, so a slow-loris peer
    /// costs O(deadlined), not O(connections), per tick.
    deadlined: BTreeSet<usize>,
    draining: bool,
}

impl Reactor {
    fn run(mut self) {
        let mut events: Vec<PollEvent> = Vec::new();
        loop {
            if !self.draining && self.shared.stop.load(Ordering::Acquire) {
                self.begin_drain();
            }
            if self.draining && self.conns.iter().flatten().count() == 0 {
                return;
            }
            let now = Instant::now();
            let timeout = self.poll_timeout(now);
            if self.poller.poll(&mut events, timeout).is_err() {
                // a broken poller cannot drive any connection; bail
                return;
            }
            let now = Instant::now();
            for &ev in &events {
                match ev.token {
                    TOKEN_LISTENER => self.on_accept(now),
                    TOKEN_WAKER => self.wake_rx.drain(),
                    t => self.on_conn_event(t - TOKEN_CONN0, ev, now),
                }
            }
            self.flush_dirty(now);
            self.check_deadlines(now);
            if self.draining {
                self.drain_sweep(now);
            }
        }
    }

    /// How long the next poll may block: until the nearest armed
    /// deadline (accept backoff, frame timeouts, lingers), 50 ms while
    /// draining, otherwise indefinitely — an idle server makes zero
    /// syscalls until a peer or responder acts.
    fn poll_timeout(&self, now: Instant) -> Option<Duration> {
        let mut next: Option<Instant> = self.accept_backoff_until;
        for &idx in &self.deadlined {
            if let Some(c) = self.conns.get(idx).and_then(|s| s.as_ref()) {
                for t in [c.frame_deadline, c.linger_deadline].into_iter().flatten() {
                    next = Some(next.map_or(t, |n| n.min(t)));
                }
            }
        }
        if self.draining {
            let cap = now + DRAIN_POLL;
            next = Some(next.map_or(cap, |n| n.min(cap)));
        }
        poll::timeout_until(next, now)
    }

    fn begin_drain(&mut self) {
        self.draining = true;
        // no new peers; the backlog is abandoned to the process exit
        let _ = self.poller.deregister(TOKEN_LISTENER);
    }

    /// Accept everything pending. On a transient error, count it and
    /// mask the listener for a distinct backoff — unlike the old
    /// thread-per-conn loop, the idle path shares nothing with this
    /// (idle costs no syscall at all), so the two cannot be conflated.
    fn on_accept(&mut self, now: Instant) {
        if self.draining || self.accept_backoff_until.is_some() {
            return;
        }
        loop {
            match self.listener.accept() {
                Ok((stream, _peer)) => self.admit(stream, now),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => {
                    self.shared.metrics.accept_errors.fetch_add(1, Ordering::Relaxed);
                    self.accept_backoff_until = Some(now + ACCEPT_ERROR_BACKOFF);
                    let _ = self.poller.reregister(TOKEN_LISTENER, Interest::NONE);
                    break;
                }
            }
        }
    }

    fn admit(&mut self, stream: TcpStream, now: Instant) {
        let _ = stream.set_nonblocking(true);
        let _ = stream.set_nodelay(true);
        let m = &self.shared.metrics;
        if m.active.load(Ordering::Relaxed) >= self.max_conns {
            m.rejected_connections.fetch_add(1, Ordering::Relaxed);
            if self.shed_live >= MAX_SHED_CONNS {
                return; // flood: drop without the courtesy frame
            }
            let mut conn = Conn::new(stream, false);
            conn.state = ConnState::Closing;
            conn.linger_deadline = Some(now + self.tuning.linger);
            conn.outbox.push(
                &Frame::Error {
                    id: 0,
                    code: ErrorCode::Busy,
                    message: "connection cap reached".to_string(),
                }
                .encode(),
            );
            let idx = self.install(conn);
            self.shed_live += 1;
            self.deadlined.insert(idx);
            self.after_io(idx, now);
            return;
        }
        let active = m.active.fetch_add(1, Ordering::Relaxed) + 1;
        m.peak_active.fetch_max(active, Ordering::Relaxed);
        m.accepted.fetch_add(1, Ordering::Relaxed);
        let idx = self.install(Conn::new(stream, true));
        // the peer may already have pipelined requests into the kernel
        self.on_conn_event(
            idx,
            PollEvent { token: idx + TOKEN_CONN0, readable: true, writable: false, error: false },
            now,
        );
    }

    fn install(&mut self, conn: Conn) -> usize {
        let idx = match self.free.pop() {
            Some(i) => i,
            None => {
                self.conns.push(None);
                self.conns.len() - 1
            }
        };
        let src = source(&conn.stream);
        let interest = conn.desired_interest();
        let _ = self.poller.register(src, idx + TOKEN_CONN0, interest);
        let mut conn = conn;
        conn.interest = interest;
        self.conns[idx] = Some(conn);
        idx
    }

    fn close(&mut self, idx: usize) {
        let Some(c) = self.conns.get_mut(idx).and_then(Option::take) else {
            return;
        };
        let _ = self.poller.deregister(idx + TOKEN_CONN0);
        // pending responders now drop their frames instead of queueing
        // into a socket nobody will flush
        c.outbox.mark_dead();
        if c.counted {
            self.shared.metrics.active.fetch_sub(1, Ordering::Relaxed);
        } else {
            self.shed_live = self.shed_live.saturating_sub(1);
        }
        self.deadlined.remove(&idx);
        self.free.push(idx);
    }

    fn on_conn_event(&mut self, idx: usize, ev: PollEvent, now: Instant) {
        if self.conns.get(idx).is_none_or(|s| s.is_none()) {
            return;
        }
        if ev.error {
            self.close(idx);
            return;
        }
        if ev.readable && !self.on_readable(idx, now) {
            return; // closed
        }
        self.after_io(idx, now);
    }

    /// Read + parse pass for one connection. Returns false when the
    /// connection was closed.
    fn on_readable(&mut self, idx: usize, now: Instant) -> bool {
        let (fill, state) = {
            let c = self.conns[idx].as_mut().unwrap();
            (c.fill(), c.state)
        };
        if fill.gone {
            self.close(idx);
            return false;
        }
        match state {
            ConnState::Closing => {
                // absorb-and-discard so the eventual close is a FIN
                self.conns[idx].as_mut().unwrap().discard_input();
            }
            ConnState::Open => {
                loop {
                    let next = self.conns[idx].as_mut().unwrap().next_frame();
                    match next {
                        None => break,
                        Some(Ok(frame)) => {
                            if !self.dispatch(idx, frame, now) {
                                break; // strict close: stop parsing
                            }
                        }
                        Some(Err(e)) => {
                            self.protocol_error(idx, &e, now);
                            break;
                        }
                    }
                }
                let frame_timeout = self.tuning.frame_timeout;
                let (partial, eof, owes) = {
                    let Some(c) = self.conns.get_mut(idx).and_then(|s| s.as_mut()) else {
                        return false;
                    };
                    if c.state != ConnState::Open {
                        // a dispatch flipped it to Closing
                        c.discard_input();
                        return true;
                    }
                    // slow-loris guard: a partial frame arms a hard
                    // completion deadline; a completed buffer disarms it
                    let arm = if c.has_partial() {
                        if c.frame_deadline.is_none() {
                            c.frame_deadline = Some(now + frame_timeout);
                            true
                        } else {
                            false
                        }
                    } else {
                        c.frame_deadline = None;
                        false
                    };
                    if arm {
                        self.deadlined.insert(idx);
                    }
                    let c = self.conns[idx].as_ref().unwrap();
                    (
                        c.has_partial(),
                        c.peer_eof,
                        c.in_flight.load(Ordering::Acquire) > 0 || c.has_pending_output(),
                    )
                };
                if eof {
                    if partial {
                        // EOF mid-frame: same strict close the stream
                        // decoder used to produce
                        self.protocol_error(idx, &WireError::Truncated, now);
                    } else if owes {
                        // half-close with replies owed: flush them,
                        // bounded by a grace deadline
                        if let Some(c) = self.conns.get_mut(idx).and_then(|s| s.as_mut()) {
                            if c.linger_deadline.is_none() {
                                c.linger_deadline = Some(now + EOF_WRITE_GRACE);
                                self.deadlined.insert(idx);
                            }
                        }
                    }
                    // a clean, fully-quiet EOF closes in after_io
                }
            }
        }
        true
    }

    /// Handle one parsed frame. Returns false once the connection has
    /// flipped to [`ConnState::Closing`] (stop parsing its buffer).
    fn dispatch(&mut self, idx: usize, frame: Frame, now: Instant) -> bool {
        match frame {
            Frame::Request { id, model, context, features, trace } => {
                self.handle_request(idx, id, model, context, features, trace);
                true
            }
            Frame::HealthRequest => {
                let f = self.shared.health_frame();
                self.queue_frame(idx, &f);
                true
            }
            Frame::MetricsRequest { model } => {
                let f = self.metrics_frame(&model);
                self.queue_frame(idx, &f);
                true
            }
            Frame::Shutdown => {
                self.queue_frame(idx, &Frame::Shutdown);
                let mut req = lock_unpoisoned(&self.shared.shutdown_requested);
                *req = true;
                self.shared.shutdown_cv.notify_all();
                true
            }
            _ => {
                // server-to-client frame types arriving here mean a
                // confused peer: strict close
                self.queue_frame(
                    idx,
                    &Frame::Error {
                        id: 0,
                        code: ErrorCode::BadRequest,
                        message: "unexpected frame type".to_string(),
                    },
                );
                self.begin_close(idx, now);
                false
            }
        }
    }

    fn metrics_frame(&self, model: &str) -> Frame {
        let shared = &self.shared;
        shared
            .batchers
            .get(model)
            .and_then(|b| model_metrics_snapshot(&shared.svc, b))
            .map(|mut snap| {
                snap.net_accept_errors = shared.metrics.accept_errors.load(Ordering::Relaxed);
                snap.net_shed_connections =
                    shared.metrics.rejected_connections.load(Ordering::Relaxed);
                Frame::MetricsReply(snap)
            })
            .unwrap_or_else(|| Frame::Error {
                id: 0,
                code: ErrorCode::UnknownModel,
                message: format!("model '{}' not served", shorten(model)),
            })
    }

    /// Validate and enqueue one request; the responder queues the
    /// Response or Error frame from a batcher thread and wakes the
    /// reactor to flush it.
    fn handle_request(
        &mut self,
        idx: usize,
        id: u64,
        model: String,
        context: u32,
        features: Vec<f32>,
        trace: Option<u64>,
    ) {
        if self.shared.stop.load(Ordering::Acquire) {
            self.queue_frame(
                idx,
                &Frame::Error {
                    id,
                    code: ErrorCode::Stopped,
                    message: "server draining".to_string(),
                },
            );
            return;
        }
        let Some(batcher) = self.shared.batchers.get(&model).cloned() else {
            self.queue_frame(
                idx,
                &Frame::Error {
                    id,
                    code: ErrorCode::UnknownModel,
                    message: format!("model '{}' not served", shorten(&model)),
                },
            );
            return;
        };
        if (context as usize) >= batcher.contexts() {
            self.queue_frame(
                idx,
                &Frame::Error {
                    id,
                    code: ErrorCode::BadRequest,
                    message: format!(
                        "context {} out of range (model '{}' hosts {} contexts)",
                        context,
                        shorten(&model),
                        batcher.contexts()
                    ),
                },
            );
            return;
        }
        if features.len() != batcher.features() {
            self.queue_frame(
                idx,
                &Frame::Error {
                    id,
                    code: ErrorCode::BadRequest,
                    message: format!(
                        "feature dim {} != model dim {}",
                        features.len(),
                        batcher.features()
                    ),
                },
            );
            return;
        }
        self.shared.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let Some((outbox, in_flight)) = self
            .conns
            .get(idx)
            .and_then(|s| s.as_ref())
            .map(|c| (Arc::clone(&c.outbox), Arc::clone(&c.in_flight)))
        else {
            return;
        };
        // the trace is minted here, at the front door: a client-supplied
        // trace ID wins, otherwise the sampler decides (one branch when
        // sampling is off). The ReqTrace rides the request through the
        // batcher and engine; the enclosing "net" span is recorded by
        // the responder below, from the trace's birth to reply-queueing.
        let req_trace = trace
            .or_else(|| self.shared.sampler.sample())
            .map(|tid| ReqTrace::new(tid, Arc::clone(&self.shared.trace_sink)));
        let net_t0 = req_trace.as_ref().map(|tr| tr.t0());
        in_flight.fetch_add(1, Ordering::AcqRel);
        let shared = Arc::clone(&self.shared);
        batcher.enqueue(BatchItem {
            features,
            context: context as usize,
            trace: req_trace,
            respond: Box::new(move |res| {
                let frame = match res {
                    Ok(p) => {
                        if let (Some(echo), Some(t0)) = (p.trace, net_t0) {
                            shared.trace_sink.record(
                                echo.trace_id,
                                "net",
                                "net",
                                t0,
                                Instant::now(),
                                0,
                            );
                        }
                        Frame::Response {
                            id,
                            class: p.class as u32,
                            latency_us: p.latency.as_micros() as u64,
                            batch_occupancy: p.batch_occupancy as u32,
                            worker: p.worker as u32,
                            trace: p.trace,
                        }
                    }
                    Err(e) => Frame::Error {
                        id,
                        code: code_for(e),
                        message: e.to_string(),
                    },
                };
                // order matters for the drain path: the frame must be
                // queued before in_flight drops, so the reactor never
                // observes "drained" with a response still unqueued
                push_counted(&shared.metrics, &outbox, &frame);
                in_flight.fetch_sub(1, Ordering::AcqRel);
                lock_unpoisoned(&shared.dirty).push(idx);
                shared.waker.wake();
            }),
        });
    }

    fn queue_frame(&mut self, idx: usize, frame: &Frame) {
        if let Some(c) = self.conns.get(idx).and_then(|s| s.as_ref()) {
            push_counted(&self.shared.metrics, &c.outbox, frame);
        }
    }

    /// Undecodable input: count it, queue one best-effort error frame,
    /// strict close.
    fn protocol_error(&mut self, idx: usize, e: &WireError, now: Instant) {
        self.shared.metrics.wire_errors.fetch_add(1, Ordering::Relaxed);
        self.queue_frame(
            idx,
            &Frame::Error {
                id: 0,
                code: ErrorCode::BadRequest,
                message: format!("protocol error: {e}"),
            },
        );
        self.begin_close(idx, now);
    }

    /// Flip a connection to the lingering-close state: stop parsing,
    /// flush what is queued, absorb peer bytes, close on flushed-EOF or
    /// linger expiry.
    fn begin_close(&mut self, idx: usize, now: Instant) {
        let linger = self.tuning.linger;
        if let Some(c) = self.conns.get_mut(idx).and_then(|s| s.as_mut()) {
            c.state = ConnState::Closing;
            c.discard_input();
            c.frame_deadline = None;
            if c.linger_deadline.is_none() {
                c.linger_deadline = Some(now + linger);
            }
            self.deadlined.insert(idx);
        }
    }

    /// Post-I/O bookkeeping for one connection: flush staged output,
    /// apply the close rules, converge poller interest.
    fn after_io(&mut self, idx: usize, _now: Instant) {
        let Some(c) = self.conns.get_mut(idx).and_then(|s| s.as_mut()) else {
            return;
        };
        match c.flush() {
            FlushOutcome::Gone => {
                self.close(idx);
                return;
            }
            FlushOutcome::Flushed | FlushOutcome::Blocked => {}
        }
        let c = self.conns[idx].as_ref().unwrap();
        let quiet = !c.has_pending_output() && c.in_flight.load(Ordering::Acquire) == 0;
        let done = match c.state {
            // a closing connection ends once its final frames are out
            // and the peer has hung up (the linger deadline bounds a
            // peer that never does)
            ConnState::Closing => quiet && c.peer_eof,
            // an open connection ends on a fully-quiet clean EOF
            ConnState::Open => quiet && c.peer_eof && !c.has_partial(),
        };
        if done {
            self.close(idx);
            return;
        }
        self.update_interest(idx);
    }

    fn update_interest(&mut self, idx: usize) {
        let Some(c) = self.conns.get_mut(idx).and_then(|s| s.as_mut()) else {
            return;
        };
        let want = c.desired_interest();
        if want != c.interest {
            c.interest = want;
            let _ = self.poller.reregister(idx + TOKEN_CONN0, want);
        }
    }

    /// Flush every connection a responder marked dirty since the last
    /// pass. Duplicate and stale tokens are harmless (a reused slot
    /// flushes its own outbox; a freed slot is skipped).
    fn flush_dirty(&mut self, now: Instant) {
        let mut dirty = std::mem::take(&mut *lock_unpoisoned(&self.shared.dirty));
        if dirty.is_empty() {
            return;
        }
        dirty.sort_unstable();
        dirty.dedup();
        for idx in dirty {
            self.after_io(idx, now);
        }
    }

    /// Fire due deadlines: unmask the listener after accept backoff,
    /// close expired lingers, strict-close slow-loris partial frames.
    fn check_deadlines(&mut self, now: Instant) {
        if let Some(t) = self.accept_backoff_until {
            if now >= t {
                self.accept_backoff_until = None;
                if !self.draining {
                    let _ = self.poller.reregister(TOKEN_LISTENER, Interest::READ);
                    // peers may have queued in the backlog meanwhile
                    self.on_accept(now);
                }
            }
        }
        if self.deadlined.is_empty() {
            return;
        }
        let due: Vec<usize> = self.deadlined.iter().copied().collect();
        for idx in due {
            let Some(c) = self.conns.get(idx).and_then(|s| s.as_ref()) else {
                self.deadlined.remove(&idx);
                continue;
            };
            let linger_due = c.linger_deadline.is_some_and(|t| now >= t);
            let frame_due = c.frame_deadline.is_some_and(|t| now >= t);
            let any_armed = c.linger_deadline.is_some() || c.frame_deadline.is_some();
            if linger_due {
                self.close(idx);
            } else if frame_due {
                // slow-loris: the partial frame did not complete in time
                self.protocol_error(idx, &WireError::Truncated, now);
                self.after_io(idx, now);
            } else if !any_armed {
                self.deadlined.remove(&idx);
            }
        }
    }

    /// While draining: answer nothing new, flush everything owed, and
    /// close each connection the moment it owes nothing — bounded per
    /// connection by a write-grace linger against stalled peers.
    fn drain_sweep(&mut self, now: Instant) {
        for idx in 0..self.conns.len() {
            if self.conns[idx].is_none() {
                continue;
            }
            self.after_io(idx, now);
            let Some(c) = self.conns.get_mut(idx).and_then(|s| s.as_mut()) else {
                continue;
            };
            if c.in_flight.load(Ordering::Acquire) > 0 {
                continue; // responders still owe frames; the wake loop returns here
            }
            if !c.has_pending_output() {
                self.close(idx);
                continue;
            }
            if c.linger_deadline.is_none() {
                c.linger_deadline = Some(now + EOF_WRITE_GRACE);
                self.deadlined.insert(idx);
            }
        }
    }
}
