//! Threaded TCP front-end over the in-process [`InferenceService`].
//!
//! [`NetServer`] is the network boundary the rest of the crate never
//! had: a `std::net` accept loop (no tokio — the design note in
//! [`crate::coordinator::server`] applies: offline build, compute-bound
//! request path) that speaks the [`crate::net::wire`] protocol and feeds
//! every `Request` frame through a per-model
//! [`MicroBatcher`](crate::net::MicroBatcher) so concurrent socket
//! traffic reaches the engine as coalesced batches.
//!
//! - **Per-connection handler threads.** Each accepted connection gets
//!   one reader thread. Responses are written by batcher completion
//!   threads through a mutex-shared writer, so a connection can pipeline
//!   many requests before reading any response (frames carry ids).
//! - **Connection cap.** Beyond [`NetServerConfig::max_connections`]
//!   live connections, a new peer receives one `Error{Busy}` frame and
//!   is closed — explicit shed, mirroring the engine's bounded shards.
//! - **Graceful drain-then-shutdown.** [`NetServer::shutdown`] stops
//!   accepting, lets every accepted request finish (handlers exit once
//!   their in-flight count drains; batchers flush partial groups
//!   immediately), then joins every thread. A client can request the
//!   same drain remotely with a `Shutdown` frame —
//!   [`NetServer::run_until_shutdown`] blocks until one arrives.
//! - **Strict decode.** An undecodable frame gets one best-effort
//!   `Error{BadRequest}` frame and the connection is closed; the server
//!   never guesses at resynchronization.

use std::collections::BTreeMap;
use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::Result;

use super::batcher::{BatchItem, BatcherConfig, BatcherHandle, MicroBatcher};
use super::wire::{
    read_frame, write_frame, ErrorCode, Frame, MetricsSnapshot, ModelInfo, WireError,
};
use crate::coordinator::{InferenceService, ServeError};

/// How long a handler's blocking read waits before re-checking the
/// server's stop flag (bounds shutdown latency per connection).
const READ_POLL: Duration = Duration::from_millis(100);
/// Accept-loop poll interval while the listener has no pending peer.
const ACCEPT_POLL: Duration = Duration::from_millis(1);
/// Cap on concurrent shed threads (the polite Busy-frame goodbye takes
/// up to ~1.4 s against a non-reading peer). Beyond it, over-cap
/// connections are dropped outright — under a connect flood the
/// resource bound matters more than the courtesy frame.
const MAX_SHED_THREADS: usize = 32;

/// Tuning knobs for the TCP front-end.
#[derive(Clone, Copy, Debug)]
pub struct NetServerConfig {
    /// Live-connection cap; peers beyond it are shed with one
    /// `Error{Busy}` frame (CLI: `--max-conns`).
    pub max_connections: usize,
    /// Micro-batcher flush deadline — *the* latency/throughput knob of
    /// the socket path, armed when a group's first request arrives
    /// (CLI: `serve --listen ... --batch-window USEC`; 0 = flush every
    /// request immediately).
    pub batch_window: Duration,
}

impl Default for NetServerConfig {
    fn default() -> Self {
        NetServerConfig {
            max_connections: 64,
            batch_window: Duration::from_millis(1),
        }
    }
}

/// Network-layer counters (the engine layer keeps its own
/// [`crate::coordinator::ModelMetrics`]). All atomics, readable at any
/// time with `Ordering::Relaxed`.
#[derive(Debug, Default)]
pub struct NetMetrics {
    /// Connections accepted and handled.
    pub accepted: AtomicU64,
    /// Connections shed at the cap with `Error{Busy}`.
    pub rejected_connections: AtomicU64,
    /// Valid request frames received (including ones the micro-batcher
    /// then shed synchronously with `Busy`/`Stopped`; reconcile against
    /// [`crate::net::BatcherMetrics::rejected`] for admitted-only
    /// counts).
    pub requests: AtomicU64,
    /// Response frames written (successful predictions).
    pub responses: AtomicU64,
    /// Error frames written (per-request and connection-level).
    pub errors: AtomicU64,
    /// Connections dropped on an undecodable frame.
    pub wire_errors: AtomicU64,
    /// Currently open connections (gauge).
    pub active: AtomicUsize,
}

/// Shared state between the accept loop, the handlers, and the owner.
struct ServerShared {
    /// The engine service (handlers read its metrics for
    /// `MetricsRequest` frames; submissions go through the batchers'
    /// own clients).
    svc: Arc<InferenceService>,
    /// Set by [`NetServer::shutdown`]: stop accepting, drain, exit.
    stop: AtomicBool,
    /// Set when a peer sends a `Shutdown` frame; wakes
    /// [`NetServer::run_until_shutdown`].
    shutdown_requested: Mutex<bool>,
    shutdown_cv: Condvar,
    metrics: NetMetrics,
    /// Per-model enqueue handles (immutable after startup).
    batchers: BTreeMap<String, BatcherHandle>,
    /// Live handler threads; the accept loop pushes, shutdown joins.
    conns: Mutex<Vec<JoinHandle<()>>>,
}

impl ServerShared {
    fn health_frame(&self) -> Frame {
        Frame::HealthReply {
            draining: self.stop.load(Ordering::Acquire),
            active_connections: self.metrics.active.load(Ordering::Relaxed) as u32,
            models: self
                .batchers
                .values()
                .map(|b| ModelInfo {
                    name: b.model().to_string(),
                    features: b.features() as u32,
                    classes: b.classes() as u32,
                    batch: b.batch() as u32,
                    contexts: b.contexts() as u32,
                })
                .collect(),
        }
    }
}

/// The TCP front-end. See the module docs for the architecture.
///
/// Startup takes the service as an `Arc` so the owner can keep an
/// in-process [`crate::coordinator::Client`] to the very same engines —
/// which is how the end-to-end tests prove socket inference bit-identical
/// to in-process inference. [`NetServer::shutdown`] hands the `Arc`
/// back after the network drain, so the owner decides when the engine
/// workers stop.
pub struct NetServer {
    svc: Arc<InferenceService>,
    shared: Arc<ServerShared>,
    accept: Option<JoinHandle<()>>,
    batchers: Vec<MicroBatcher>,
    addr: SocketAddr,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port), spawn
    /// one micro-batcher per served model and the accept loop, and
    /// return immediately. The bound address is [`NetServer::local_addr`].
    pub fn start(
        svc: Arc<InferenceService>,
        addr: impl ToSocketAddrs,
        cfg: NetServerConfig,
    ) -> Result<NetServer> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let mut batchers = Vec::new();
        let mut handles = BTreeMap::new();
        for model in svc.models() {
            let client = svc.client(&model)?;
            let bcfg = BatcherConfig::for_client(&client, cfg.batch_window);
            let b = MicroBatcher::start(client, bcfg);
            handles.insert(model, b.handle());
            batchers.push(b);
        }
        let shared = Arc::new(ServerShared {
            svc: Arc::clone(&svc),
            stop: AtomicBool::new(false),
            shutdown_requested: Mutex::new(false),
            shutdown_cv: Condvar::new(),
            metrics: NetMetrics::default(),
            batchers: handles,
            conns: Mutex::new(Vec::new()),
        });
        let accept = {
            let shared = Arc::clone(&shared);
            let max_conns = cfg.max_connections.max(1);
            std::thread::spawn(move || accept_loop(listener, shared, max_conns))
        };
        Ok(NetServer {
            svc,
            shared,
            accept: Some(accept),
            batchers,
            addr,
        })
    }

    /// The address the listener actually bound (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Network-layer counters.
    pub fn metrics(&self) -> &NetMetrics {
        &self.shared.metrics
    }

    /// The served models' metrics snapshot as sent to clients
    /// (engine counters + this server's micro-batcher coalescing).
    pub fn model_snapshot(&self, model: &str) -> Option<MetricsSnapshot> {
        model_metrics_snapshot(&self.svc, self.shared.batchers.get(model)?)
    }

    /// Enqueue handle of `model`'s micro-batcher. The handle stays
    /// valid (for metrics reads) after [`NetServer::shutdown`], which
    /// is how the CLI reports final post-drain coalescing numbers.
    pub fn batcher(&self, model: &str) -> Option<BatcherHandle> {
        self.shared.batchers.get(model).cloned()
    }

    /// Block until a peer requests drain with a `Shutdown` frame (or
    /// [`NetServer::shutdown`] is invoked from another thread). The CLI
    /// parks here between "listening" and the drain.
    pub fn run_until_shutdown(&self) {
        let mut requested = self.shared.shutdown_requested.lock().unwrap();
        while !*requested && !self.shared.stop.load(Ordering::Acquire) {
            let (guard, _) = self
                .shared
                .shutdown_cv
                .wait_timeout(requested, Duration::from_millis(200))
                .unwrap();
            requested = guard;
        }
    }

    /// Drain-then-shutdown of the *network* layer: stop accepting, let
    /// every admitted request finish, join the accept loop, every
    /// connection handler and every batcher thread — then hand the
    /// inference service back to the owner (who calls
    /// [`InferenceService::shutdown`] once no other `Arc`s remain).
    pub fn shutdown(mut self) -> Result<Arc<InferenceService>> {
        self.shared.stop.store(true, Ordering::Release);
        self.shared.shutdown_cv.notify_all();
        // flush queued partial groups now, so the handler drain below is
        // bounded by engine execution time, not by the batch window
        for b in &self.batchers {
            b.request_stop();
        }
        // a panicked thread is reported, but never short-circuits the
        // teardown: every remaining thread is still joined and every
        // batcher still drained before the error surfaces
        let mut first_err: Option<anyhow::Error> = None;
        if let Some(h) = self.accept.take() {
            if h.join().is_err() {
                first_err = Some(anyhow::anyhow!("accept loop panicked"));
            }
        }
        // handlers exit once stopped + their in-flight replies drained
        let conns = std::mem::take(&mut *self.shared.conns.lock().unwrap());
        for h in conns {
            if h.join().is_err() && first_err.is_none() {
                first_err = Some(anyhow::anyhow!("connection handler panicked"));
            }
        }
        // batchers flush partial groups immediately on stop and join
        // their completion threads, so every admitted request has been
        // answered by the time this returns
        for b in self.batchers.drain(..) {
            b.shutdown();
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(Arc::clone(&self.svc)),
        }
    }
}

impl Drop for NetServer {
    /// Dropping without [`NetServer::shutdown`] still signals every
    /// thread to stop; they drain detached rather than joined.
    fn drop(&mut self) {
        self.shared.stop.store(true, Ordering::Release);
        self.shared.shutdown_cv.notify_all();
    }
}

/// Build the combined engine + micro-batcher metrics snapshot for one
/// model — what a `MetricsReply` frame carries, also usable after
/// [`NetServer::shutdown`] with the returned service and a
/// [`BatcherHandle`] to report final post-drain numbers.
pub fn model_metrics_snapshot(
    svc: &InferenceService,
    batcher: &BatcherHandle,
) -> Option<MetricsSnapshot> {
    let model = batcher.model().to_string();
    let m = svc.metrics(&model)?;
    let bm = batcher.metrics();
    Some(MetricsSnapshot {
        model,
        contexts: batcher.contexts() as u64,
        requests: m.requests.load(Ordering::Relaxed),
        rejected: m.rejected.load(Ordering::Relaxed),
        batches: m.batches.load(Ordering::Relaxed),
        padded_rows: m.padded_rows.load(Ordering::Relaxed),
        stolen: m.stolen.load(Ordering::Relaxed),
        quant_saturations: m.quant_saturations.load(Ordering::Relaxed),
        p50_us: m.latency.quantile(0.50).as_micros() as u64,
        p95_us: m.latency.quantile(0.95).as_micros() as u64,
        p99_us: m.latency.quantile(0.99).as_micros() as u64,
        mean_occupancy: m.mean_occupancy(),
        net_flushes: bm.flushes.load(Ordering::Relaxed),
        net_coalesced: bm.coalesced.load(Ordering::Relaxed),
    })
}

fn accept_loop(listener: TcpListener, shared: Arc<ServerShared>, max_conns: usize) {
    // live shed threads (detached, bounded by MAX_SHED_THREADS)
    let shedding = Arc::new(AtomicUsize::new(0));
    loop {
        if shared.stop.load(Ordering::Acquire) {
            return;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                let m = &shared.metrics;
                if m.active.load(Ordering::Relaxed) >= max_conns {
                    m.rejected_connections.fetch_add(1, Ordering::Relaxed);
                    // shed on a short-lived detached thread: the write
                    // timeout + lingering close can take over a second
                    // against a non-reading peer, and the accept loop
                    // must keep accepting meanwhile. Under a connect
                    // flood the shed threads themselves are capped —
                    // past the cap the connection is dropped without
                    // the courtesy frame.
                    if shedding.load(Ordering::Relaxed) < MAX_SHED_THREADS {
                        shedding.fetch_add(1, Ordering::Relaxed);
                        let shedding = Arc::clone(&shedding);
                        std::thread::spawn(move || {
                            shed_connection(stream);
                            shedding.fetch_sub(1, Ordering::Relaxed);
                        });
                    }
                    continue;
                }
                m.active.fetch_add(1, Ordering::Relaxed);
                m.accepted.fetch_add(1, Ordering::Relaxed);
                let shared2 = Arc::clone(&shared);
                let handle =
                    std::thread::spawn(move || handle_connection(stream, shared2));
                let mut conns = shared.conns.lock().unwrap();
                // reap finished handlers so a long-lived server does not
                // accumulate dead JoinHandles
                conns.retain(|h| !h.is_finished());
                conns.push(handle);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(_) => {
                if shared.stop.load(Ordering::Acquire) {
                    return;
                }
                std::thread::sleep(ACCEPT_POLL);
            }
        }
    }
}

/// Over-cap peer: one best-effort Busy frame, then close.
fn shed_connection(mut stream: TcpStream) {
    // see handle_connection: accepted sockets can inherit non-blocking
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_nodelay(true);
    let _ = stream.set_write_timeout(Some(Duration::from_secs(1)));
    let _ = write_frame(
        &mut stream,
        &Frame::Error {
            id: 0,
            code: ErrorCode::Busy,
            message: "connection cap reached".to_string(),
        },
    );
    let _ = stream.flush();
    drain_before_close(&mut stream);
}

/// Absorb whatever the peer already sent before dropping a connection.
/// Closing a socket with unread received bytes makes the kernel answer
/// with RST, which can discard the error frame we just wrote out of the
/// peer's receive buffer — draining first turns the close into a clean
/// FIN so the peer reliably reads its `Error` frame.
fn drain_before_close(stream: &mut TcpStream) {
    let _ = stream.set_read_timeout(Some(Duration::from_millis(50)));
    let mut scratch = [0u8; 4096];
    for _ in 0..8 {
        match std::io::Read::read(stream, &mut scratch) {
            Ok(0) | Err(_) => break,
            Ok(_) => {}
        }
    }
}

/// Truncate a client-supplied string before echoing it into an error
/// message: wire strings are capped at u16::MAX bytes and the encoder
/// asserts on longer ones, so echoing a hostile 64 KiB model name
/// verbatim could panic the handler. 64 bytes is plenty for diagnosis.
fn shorten(s: &str) -> String {
    const MAX: usize = 64;
    if s.len() <= MAX {
        return s.to_string();
    }
    let mut end = MAX;
    while !s.is_char_boundary(end) {
        end -= 1;
    }
    format!("{}...", &s[..end])
}

/// Map an engine rejection onto a wire error code.
fn code_for(e: ServeError) -> ErrorCode {
    match e {
        ServeError::Busy => ErrorCode::Busy,
        ServeError::Stopped => ErrorCode::Stopped,
    }
}

/// Shared per-connection writer with a dead-man flag: the first failed
/// write (a non-reading peer's timeout, or a vanished peer) marks the
/// connection dead and every later frame to it is dropped. This bounds
/// the damage a stalled peer can do to the single completion thread to
/// one write-timeout total — not one per queued response — so it
/// cannot head-of-line-block other connections' replies for long.
struct ConnWriter {
    stream: Mutex<TcpStream>,
    dead: AtomicBool,
}

impl ConnWriter {
    fn new(stream: TcpStream) -> ConnWriter {
        ConnWriter {
            stream: Mutex::new(stream),
            dead: AtomicBool::new(false),
        }
    }
}

/// Serialize one frame onto the shared writer (best-effort: a vanished
/// or stalled peer is not an error worth propagating — see
/// [`ConnWriter`]).
fn send(writer: &ConnWriter, metrics: &NetMetrics, frame: &Frame) {
    if writer.dead.load(Ordering::Relaxed) {
        return;
    }
    match frame {
        Frame::Response { .. } => {
            metrics.responses.fetch_add(1, Ordering::Relaxed);
        }
        Frame::Error { .. } => {
            metrics.errors.fetch_add(1, Ordering::Relaxed);
        }
        _ => {}
    }
    let mut w = writer.stream.lock().unwrap();
    if write_frame(&mut *w, frame).is_err() {
        writer.dead.store(true, Ordering::Relaxed);
    }
}

/// One connection's reader loop. Decrements the active gauge on every
/// exit path via a guard.
fn handle_connection(stream: TcpStream, shared: Arc<ServerShared>) {
    struct ActiveGuard<'a>(&'a NetMetrics);
    impl Drop for ActiveGuard<'_> {
        fn drop(&mut self) {
            self.0.active.fetch_sub(1, Ordering::Relaxed);
        }
    }
    let _guard = ActiveGuard(&shared.metrics);
    // BSD-derived systems let accepted sockets inherit the listener's
    // non-blocking flag (Linux does not); clear it explicitly or the
    // read timeout below would be ineffective (instant EAGAIN spins)
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(READ_POLL));
    // a peer that stops reading must not park responders (and through
    // them the shutdown drain) forever on a full send buffer
    let _ = stream.set_write_timeout(Some(Duration::from_secs(5)));
    let writer = match stream.try_clone() {
        Ok(w) => Arc::new(ConnWriter::new(w)),
        Err(_) => return,
    };
    let mut reader = stream;
    // replies this connection still owes (responders not yet invoked);
    // the drain condition on shutdown
    let in_flight = Arc::new(AtomicUsize::new(0));
    loop {
        match read_frame(&mut reader) {
            Ok(None) => break, // clean close by the peer
            Err(WireError::Io(e))
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // idle poll tick; the shared drain check below decides
            }
            Ok(Some(Frame::Request {
                id,
                model,
                context,
                features,
            })) => {
                handle_request(&shared, &writer, &in_flight, id, model, context, features);
            }
            Ok(Some(Frame::HealthRequest)) => {
                send(&writer, &shared.metrics, &shared.health_frame());
            }
            Ok(Some(Frame::MetricsRequest { model })) => {
                let frame = shared
                    .batchers
                    .get(&model)
                    .and_then(|b| model_metrics_snapshot(&shared.svc, b))
                    .map(Frame::MetricsReply)
                    .unwrap_or_else(|| Frame::Error {
                        id: 0,
                        code: ErrorCode::UnknownModel,
                        message: format!("model '{}' not served", shorten(&model)),
                    });
                send(&writer, &shared.metrics, &frame);
            }
            Ok(Some(Frame::Shutdown)) => {
                send(&writer, &shared.metrics, &Frame::Shutdown);
                let mut req = shared.shutdown_requested.lock().unwrap();
                *req = true;
                shared.shutdown_cv.notify_all();
            }
            Ok(Some(_)) => {
                // server-to-client frame types arriving here mean a
                // confused peer: strict close
                send(
                    &writer,
                    &shared.metrics,
                    &Frame::Error {
                        id: 0,
                        code: ErrorCode::BadRequest,
                        message: "unexpected frame type".to_string(),
                    },
                );
                break;
            }
            Err(e) => {
                // undecodable or transport-broken: one best-effort
                // error frame, then strict close
                shared.metrics.wire_errors.fetch_add(1, Ordering::Relaxed);
                send(
                    &writer,
                    &shared.metrics,
                    &Frame::Error {
                        id: 0,
                        code: ErrorCode::BadRequest,
                        message: format!("protocol error: {e}"),
                    },
                );
                break;
            }
        }
        // drain exit — checked after EVERY frame, not only on idle
        // ticks, so a peer that keeps sending (and being answered with
        // Stopped errors) cannot keep this handler — and through the
        // join, NetServer::shutdown — alive forever
        if shared.stop.load(Ordering::Acquire) && in_flight.load(Ordering::Acquire) == 0 {
            break;
        }
    }
    // No wait on `in_flight` here: reaching this point means either the
    // peer is gone (EOF / protocol close — nobody left to write to) or
    // the drain-path break already required in_flight == 0. Responders
    // still pending in a batcher own the writer via Arc and either
    // write harmlessly to the dead socket or are resolved by the
    // batcher's own drain — parking this thread (and its connection-cap
    // slot) for up to a batch window would serve no one.
    //
    // Absorb unread peer bytes so the close is a FIN, not an RST that
    // could wipe our final error frame out of the peer's receive buffer.
    drain_before_close(&mut reader);
}

/// Validate and enqueue one request; the responder writes the Response
/// or Error frame from a batcher thread.
fn handle_request(
    shared: &Arc<ServerShared>,
    writer: &Arc<ConnWriter>,
    in_flight: &Arc<AtomicUsize>,
    id: u64,
    model: String,
    context: u32,
    features: Vec<f32>,
) {
    let metrics = &shared.metrics;
    if shared.stop.load(Ordering::Acquire) {
        send(
            writer,
            metrics,
            &Frame::Error {
                id,
                code: ErrorCode::Stopped,
                message: "server draining".to_string(),
            },
        );
        return;
    }
    let Some(batcher) = shared.batchers.get(&model) else {
        send(
            writer,
            metrics,
            &Frame::Error {
                id,
                code: ErrorCode::UnknownModel,
                message: format!("model '{}' not served", shorten(&model)),
            },
        );
        return;
    };
    if (context as usize) >= batcher.contexts() {
        send(
            writer,
            metrics,
            &Frame::Error {
                id,
                code: ErrorCode::BadRequest,
                message: format!(
                    "context {} out of range (model '{}' hosts {} contexts)",
                    context,
                    shorten(&model),
                    batcher.contexts()
                ),
            },
        );
        return;
    }
    if features.len() != batcher.features() {
        send(
            writer,
            metrics,
            &Frame::Error {
                id,
                code: ErrorCode::BadRequest,
                message: format!(
                    "feature dim {} != model dim {}",
                    features.len(),
                    batcher.features()
                ),
            },
        );
        return;
    }
    metrics.requests.fetch_add(1, Ordering::Relaxed);
    in_flight.fetch_add(1, Ordering::AcqRel);
    let writer = Arc::clone(writer);
    let in_flight = Arc::clone(in_flight);
    let shared = Arc::clone(shared);
    batcher.enqueue(BatchItem {
        features,
        context: context as usize,
        respond: Box::new(move |res| {
            let frame = match res {
                Ok(p) => Frame::Response {
                    id,
                    class: p.class as u32,
                    latency_us: p.latency.as_micros() as u64,
                    batch_occupancy: p.batch_occupancy as u32,
                    worker: p.worker as u32,
                },
                Err(e) => Frame::Error {
                    id,
                    code: code_for(e),
                    message: e.to_string(),
                },
            };
            send(&writer, &shared.metrics, &frame);
            in_flight.fetch_sub(1, Ordering::AcqRel);
        }),
    });
}
