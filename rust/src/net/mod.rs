//! Networked serving layer: the boundary between the outside world and
//! the inference engine.
//!
//! The paper's hardware earns its throughput by keeping the batch
//! pipeline full (one input per junction cycle, Sec. III); at a network
//! edge the same economics demand coalescing many small independent
//! requests into engine-sized batches. This module is that edge,
//! built on `std::net` + a readiness-driven event loop (no tokio — the
//! offline-build design note in [`crate::coordinator::server`] applies):
//!
//! - [`wire`] — length-prefixed binary protocol with a versioned frame
//!   header and strict decoding (oversized / truncated / unknown-version
//!   frames are rejected, never guessed at).
//! - [`poll`] — the minimal readiness abstraction under the reactor: a
//!   [`poll::Poller`] trait over `poll(2)` with a portable tick-based
//!   fallback, plus a loopback [`poll::Waker`] so engine completions can
//!   interrupt a blocked poll.
//! - [`conn`] — per-connection state machine: nonblocking incremental
//!   frame reads against the strict [`wire`] decoder, a shared outbox
//!   for responses, and bounded-buffer / linger bookkeeping.
//! - [`server`] — [`NetServer`]: a single reactor thread multiplexing
//!   the listener and thousands of connections, fronting an
//!   [`crate::coordinator::InferenceService`], with a connection cap
//!   with explicit `Busy` shed, graceful drain-then-shutdown, and
//!   health/metrics frames answered from the service's
//!   [`crate::obs::Registry`] snapshot, and a trace front door minting
//!   sampled request traces (`--trace-sample`, Chrome `trace_event`
//!   export via `--trace-out`).
//! - [`batcher`] — [`MicroBatcher`]: adaptive micro-batching (flush on
//!   engine-batch-full or batch-window deadline, whichever first) that
//!   turns concurrent socket traffic into coalesced engine batches
//!   instead of batch-1 calls.
//! - [`client`] — [`NetClient`]: blocking client with pipelined
//!   multi-sample support (the `pds client` subcommand and the socket
//!   load generator sit on it).
//!
//! CLI: `pds serve --listen ADDR [--batch-window USEC]` starts the
//! server; `pds client --addr ADDR ...` drives it.

pub mod batcher;
pub mod client;
pub mod conn;
pub mod poll;
pub mod server;
pub mod wire;

pub use batcher::{
    BatchItem, BatcherConfig, BatcherHandle, BatcherMetrics, MicroBatcher, Responder,
};
pub use client::{Health, NetClient, NetClientError, NetPrediction};
pub use server::{model_metrics_snapshot, NetMetrics, NetServer, NetServerConfig, ReactorTuning};
pub use wire::{ErrorCode, Frame, MetricsSnapshot, ModelInfo, WireError};
