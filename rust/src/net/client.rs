//! Blocking TCP client for the [`crate::net`] wire protocol.
//!
//! [`NetClient`] is deliberately simple — one connection, synchronous
//! calls — but supports *pipelined* multi-sample classification:
//! [`NetClient::classify_pipelined`] writes a whole group of `Request`
//! frames in one buffered burst before reading any `Response`, which is
//! exactly the traffic shape the server's micro-batcher coalesces into
//! full engine batches. Responses are matched back to requests by frame
//! id (the server may answer out of order), so results always come back
//! in submission order.

use std::io::Write;
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use super::wire::{
    encode_request, encode_request_traced, read_frame, ErrorCode, Frame, MetricsSnapshot,
    ModelInfo, WireError,
};
use crate::obs::trace::TraceEcho;

/// Why a client call failed.
#[derive(Debug)]
pub enum NetClientError {
    /// The server shed this *request* with `Busy` — explicit
    /// backpressure, retry after a backoff. A connection-level `Busy`
    /// (the connection-cap shed, after which the server closes the
    /// socket) surfaces as [`NetClientError::Remote`] instead, because
    /// retrying on that connection cannot succeed.
    Busy,
    /// The server is draining or stopped.
    Stopped,
    /// The server reported another error (bad request, unknown model,
    /// internal).
    Remote {
        /// Machine-readable failure class from the error frame.
        code: ErrorCode,
        /// Human-readable detail from the error frame.
        message: String,
    },
    /// The server closed the connection before answering.
    Closed,
    /// A protocol violation on the stream (decode failure) or an
    /// underlying transport failure.
    Wire(WireError),
    /// The server answered with a frame type that makes no sense for
    /// the call (protocol confusion).
    Unexpected,
}

impl std::fmt::Display for NetClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NetClientError::Busy => write!(f, "server busy"),
            NetClientError::Stopped => write!(f, "server stopped"),
            NetClientError::Remote { code, message } => {
                write!(f, "server error ({code}): {message}")
            }
            NetClientError::Closed => write!(f, "connection closed by server"),
            NetClientError::Wire(e) => write!(f, "wire error: {e}"),
            NetClientError::Unexpected => write!(f, "unexpected reply frame"),
        }
    }
}

impl std::error::Error for NetClientError {}

impl From<WireError> for NetClientError {
    fn from(e: WireError) -> Self {
        NetClientError::Wire(e)
    }
}

impl From<std::io::Error> for NetClientError {
    fn from(e: std::io::Error) -> Self {
        NetClientError::Wire(WireError::Io(e))
    }
}

impl NetClientError {
    fn from_error_frame(code: ErrorCode, message: String) -> NetClientError {
        match code {
            ErrorCode::Busy => NetClientError::Busy,
            ErrorCode::Stopped => NetClientError::Stopped,
            _ => NetClientError::Remote { code, message },
        }
    }
}

/// A prediction as observed over the socket (mirrors
/// [`crate::coordinator::Prediction`]; `latency` is the *server-side*
/// submit-to-reply latency carried in the response frame).
#[derive(Clone, Copy, Debug)]
pub struct NetPrediction {
    /// Argmax class of the model's logits.
    pub class: usize,
    /// Server-side submit-to-reply latency.
    pub latency: Duration,
    /// Live rows in the engine batch that served this request.
    pub batch_occupancy: usize,
    /// Index of the engine worker that ran the batch.
    pub worker: usize,
    /// Per-stage timing echo for a traced request (`None` for the
    /// untraced common case): queue wait, batch wait, and execute time
    /// as measured server-side, keyed by the trace ID.
    pub trace: Option<TraceEcho>,
}

/// Server health as reported by a `HealthReply` frame.
#[derive(Clone, Debug)]
pub struct Health {
    /// True once the server has begun drain-then-shutdown.
    pub draining: bool,
    /// Open client connections at snapshot time.
    pub active_connections: usize,
    /// Shape info for every served model.
    pub models: Vec<ModelInfo>,
}

/// Blocking client over one TCP connection (see the module docs).
pub struct NetClient {
    stream: TcpStream,
    next_id: u64,
}

impl NetClient {
    /// Connect to a [`crate::net::NetServer`] (Nagle disabled — frames
    /// are small and latency-sensitive).
    pub fn connect(addr: impl ToSocketAddrs) -> std::io::Result<NetClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(NetClient { stream, next_id: 1 })
    }

    fn read(&mut self) -> Result<Frame, NetClientError> {
        match read_frame(&mut self.stream)? {
            Some(f) => Ok(f),
            None => Err(NetClientError::Closed),
        }
    }

    /// Classify one feature vector (a pipelined group of one) against
    /// the model's base context 0.
    pub fn classify(
        &mut self,
        model: &str,
        features: Vec<f32>,
    ) -> Result<NetPrediction, NetClientError> {
        self.classify_ctx(model, 0, features)
    }

    /// Classify one feature vector against tenant context `context` of
    /// `model` (a pipelined group of one).
    pub fn classify_ctx(
        &mut self,
        model: &str,
        context: u32,
        features: Vec<f32>,
    ) -> Result<NetPrediction, NetClientError> {
        let mut preds =
            self.classify_pipelined_ctx(model, context, std::slice::from_ref(&features))?;
        Ok(preds.remove(0))
    }

    /// Classify a group of feature vectors, pipelined: every `Request`
    /// frame is written (one buffered burst, a single syscall) before
    /// any `Response` is read, results return in submission order.
    /// Samples are borrowed, so a `Busy` retry loop re-submits the same
    /// group without re-cloning it.
    ///
    /// All-or-nothing: if the server answers any sample with an error
    /// frame, the first error is returned after all replies for the
    /// group have been collected (so the stream stays in sync and the
    /// caller can simply retry the group on [`NetClientError::Busy`]).
    pub fn classify_pipelined(
        &mut self,
        model: &str,
        samples: &[Vec<f32>],
    ) -> Result<Vec<NetPrediction>, NetClientError> {
        self.classify_pipelined_ctx(model, 0, samples)
    }

    /// [`NetClient::classify_pipelined`] against a specific tenant
    /// context: the whole group is routed to `context`'s parameter bank
    /// on the server.
    pub fn classify_pipelined_ctx(
        &mut self,
        model: &str,
        context: u32,
        samples: &[Vec<f32>],
    ) -> Result<Vec<NetPrediction>, NetClientError> {
        if samples.is_empty() {
            return Ok(Vec::new());
        }
        let first_id = self.next_id;
        let mut burst = Vec::new();
        for features in samples {
            burst.extend_from_slice(&encode_request(self.next_id, model, context, features));
            self.next_id += 1;
        }
        let n = (self.next_id - first_id) as usize;
        self.stream.write_all(&burst)?;
        // collect every reply for the group, whatever the arrival order
        let mut results: Vec<Option<Result<NetPrediction, NetClientError>>> = (0..n)
            .map(|_| None)
            .collect();
        let mut seen = 0usize;
        while seen < n {
            match self.read()? {
                Frame::Response { id, class, latency_us, batch_occupancy, worker, trace }
                    if id >= first_id && id < first_id + n as u64 =>
                {
                    let slot = (id - first_id) as usize;
                    if results[slot].is_none() {
                        seen += 1;
                    }
                    results[slot] = Some(Ok(NetPrediction {
                        class: class as usize,
                        latency: Duration::from_micros(latency_us),
                        batch_occupancy: batch_occupancy as usize,
                        worker: worker as usize,
                        trace,
                    }));
                }
                Frame::Error { id, code, message }
                    if id >= first_id && id < first_id + n as u64 =>
                {
                    let slot = (id - first_id) as usize;
                    if results[slot].is_none() {
                        seen += 1;
                    }
                    results[slot] =
                        Some(Err(NetClientError::from_error_frame(code, message)));
                }
                // a connection-level error (id 0 / unknown id) aborts
                // the whole group and is NOT mapped to the retryable
                // Busy/Stopped variants: it means the connection itself
                // was rejected (e.g. the server's connection-cap shed,
                // which closes the socket right after) — retrying the
                // group on this stream could only fail again
                Frame::Error { code, message, .. } => {
                    return Err(NetClientError::Remote { code, message });
                }
                _ => return Err(NetClientError::Unexpected),
            }
        }
        results
            .into_iter()
            .map(|r| r.expect("all slots filled"))
            .collect()
    }

    /// Classify one feature vector with an explicit client-minted trace
    /// ID. The server honors the ID regardless of its own sampling
    /// setting, records the request's span tree in its trace sink, and
    /// echoes the queue/batch/execute breakdown on the prediction —
    /// what `pds client --trace` prints as a waterfall.
    pub fn classify_traced(
        &mut self,
        model: &str,
        context: u32,
        features: Vec<f32>,
        trace_id: u64,
    ) -> Result<NetPrediction, NetClientError> {
        let id = self.next_id;
        self.next_id += 1;
        self.stream
            .write_all(&encode_request_traced(id, model, context, &features, trace_id))?;
        match self.read()? {
            Frame::Response { id: rid, class, latency_us, batch_occupancy, worker, trace }
                if rid == id =>
            {
                Ok(NetPrediction {
                    class: class as usize,
                    latency: Duration::from_micros(latency_us),
                    batch_occupancy: batch_occupancy as usize,
                    worker: worker as usize,
                    trace,
                })
            }
            Frame::Error { code, message, .. } => {
                Err(NetClientError::from_error_frame(code, message))
            }
            _ => Err(NetClientError::Unexpected),
        }
    }

    /// Fetch the server's health summary (drain state, connection
    /// gauge, served models with their shapes).
    pub fn health(&mut self) -> Result<Health, NetClientError> {
        self.stream.write_all(&Frame::HealthRequest.encode())?;
        match self.read()? {
            Frame::HealthReply { draining, active_connections, models } => Ok(Health {
                draining,
                active_connections: active_connections as usize,
                models,
            }),
            Frame::Error { code, message, .. } => {
                Err(NetClientError::from_error_frame(code, message))
            }
            _ => Err(NetClientError::Unexpected),
        }
    }

    /// Fetch one model's serving counters (engine + micro-batcher).
    pub fn metrics(&mut self, model: &str) -> Result<MetricsSnapshot, NetClientError> {
        let frame = Frame::MetricsRequest { model: model.to_string() };
        self.stream.write_all(&frame.encode())?;
        match self.read()? {
            Frame::MetricsReply(s) => Ok(s),
            Frame::Error { code, message, .. } => {
                Err(NetClientError::from_error_frame(code, message))
            }
            _ => Err(NetClientError::Unexpected),
        }
    }

    /// Ask the server to drain and shut down; returns once the server
    /// acknowledges the request.
    pub fn shutdown_server(&mut self) -> Result<(), NetClientError> {
        self.stream.write_all(&Frame::Shutdown.encode())?;
        match self.read()? {
            Frame::Shutdown => Ok(()),
            Frame::Error { code, message, .. } => {
                Err(NetClientError::from_error_frame(code, message))
            }
            _ => Err(NetClientError::Unexpected),
        }
    }
}
