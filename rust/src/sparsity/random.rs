//! Random pre-defined sparsity (Sec. II-A): `|W_i|` edges placed uniformly
//! at random with no degree constraints. At low density this has
//! non-negligible probability of fully disconnecting neurons, which the
//! paper identifies as the cause of its poor low-density accuracy
//! (Sec. IV-B, blue values in Table II).

use super::config::JunctionShape;
use super::pattern::Pattern;
use crate::util::rng::Rng;

/// Place exactly `n_edges` distinct edges uniformly at random.
pub fn generate(shape: JunctionShape, n_edges: usize, rng: &mut Rng) -> Pattern {
    let total = shape.n_left * shape.n_right;
    assert!(n_edges <= total, "more edges than the FC junction holds");
    // Sample distinct cell ids; partial Fisher-Yates is O(total) memory,
    // fine at MLP scale (<= few 10^5 cells for the paper's configs).
    let cells = rng.sample_distinct(total, n_edges);
    let mut in_edges: Vec<Vec<u32>> = vec![Vec::new(); shape.n_right];
    for c in cells {
        let j = c / shape.n_left;
        let k = (c % shape.n_left) as u32;
        in_edges[j].push(k);
    }
    for row in &mut in_edges {
        row.sort_unstable();
    }
    Pattern { shape, in_edges }
}

/// Monte-Carlo estimate of the expected number of disconnected neurons at
/// a given density — quantifies the Sec. IV-B failure mode.
pub fn expected_disconnected(
    shape: JunctionShape,
    n_edges: usize,
    trials: usize,
    rng: &mut Rng,
) -> f64 {
    let mut total = 0usize;
    for _ in 0..trials {
        let p = generate(shape, n_edges, rng);
        total += p.disconnected_left() + p.disconnected_right();
    }
    total as f64 / trials as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_edge_count_and_validity() {
        let mut rng = Rng::new(0);
        let shape = JunctionShape { n_left: 50, n_right: 20 };
        for n in [1, 10, 100, 999, 1000] {
            let p = generate(shape, n, &mut rng);
            assert_eq!(p.n_edges(), n);
            p.audit().unwrap();
        }
    }

    #[test]
    fn fc_when_all_edges() {
        let mut rng = Rng::new(1);
        let shape = JunctionShape { n_left: 7, n_right: 5 };
        let p = generate(shape, 35, &mut rng);
        assert!((p.density() - 1.0).abs() < 1e-12);
        assert_eq!(p.disconnected_left() + p.disconnected_right(), 0);
    }

    #[test]
    fn low_density_disconnects_high_density_does_not() {
        // The Sec. IV-B observation: at rho=2% random patterns lose neurons,
        // at rho=50% they essentially never do.
        let mut rng = Rng::new(2);
        let shape = JunctionShape { n_left: 100, n_right: 50 };
        let sparse = expected_disconnected(shape, 100, 50, &mut rng); // rho = 2%
        let dense = expected_disconnected(shape, 2500, 50, &mut rng); // rho = 50%
        assert!(sparse > 5.0, "sparse: {sparse}");
        assert_eq!(dense, 0.0);
    }

    #[test]
    fn generally_not_structured() {
        let mut rng = Rng::new(3);
        let shape = JunctionShape { n_left: 100, n_right: 50 };
        let p = generate(shape, 500, &mut rng);
        assert!(!p.is_structured());
    }
}
