//! Clash-free connection patterns (Sec. III-C, Appendix C).
//!
//! Left-bank storage layout (Fig. 2b / Fig. 4): left neuron `n` lives in
//! memory `n % z` at address `n / z`; the bank has `z` memories of depth
//! `D = N_l / z`. A pattern is defined by the address each of the `z`
//! lanes reads every cycle; one element per memory per cycle = clash-free
//! by construction. Edges are numbered sequentially by right neuron
//! (Sec. III-B): cycle `t` processes edges `[t*z, (t+1)*z)`, lane `m`
//! carries edge `t*z + m`, and edge `e` terminates at right neuron
//! `e / d_in`.
//!
//! Three flavors (Appendix C, Fig. 13), each optionally memory-dithered:
//! - Type 1: one seed vector `phi`, addresses advance cyclically
//!   (`addr = (phi[m] + c) mod D`), identical every sweep. Hardware cost:
//!   store `phi`, use `z` incrementers.
//! - Type 2: a fresh seed vector per sweep (our earlier FPGA work [40]).
//! - Type 3: an arbitrary per-sweep address matrix `Phi in {0..D-1}^{D x z}`
//!   whose columns are permutations (full access-sequence storage).
//!
//! Generation first draws the *symbolic* generator state ([`ScheduleSpec`]),
//! proves clash-freedom from that structure alone
//! ([`ScheduleSpec::prove_clash_free`] — always on, including release
//! builds), and only then materializes the concrete [`AccessSchedule`].
//! Violations are reported as typed [`ClashError`] counterexamples
//! (junction / cycle / memory bank).

use super::config::JunctionShape;
use super::pattern::Pattern;
use crate::util::rng::Rng;

/// A clash-freedom violation, carrying enough context (junction, cycle,
/// memory bank) to point at the offending hardware access. Produced by
/// both the symbolic prover ([`ScheduleSpec::prove_clash_free`]) and the
/// concrete replay ([`AccessSchedule::verify_clash_free`]); `junction`
/// is 0 for a schedule checked in isolation — callers that know the
/// owning junction stamp it with [`ClashError::at_junction`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ClashError {
    /// An access names a memory or address outside the `z x depth` bank.
    OutOfRange {
        /// Junction index (0 when checked in isolation).
        junction: usize,
        /// Cycle of the offending access.
        cycle: usize,
        /// Memory (bank) named by the access.
        memory: usize,
        /// Address named by the access.
        address: usize,
    },
    /// Two lanes read the same memory (bank) in the same cycle — the
    /// defining clash of Sec. III-B.
    MemoryClash {
        /// Junction index (0 when checked in isolation).
        junction: usize,
        /// First cycle in which the bank is hit twice.
        cycle: usize,
        /// The doubly-accessed memory (bank).
        memory: usize,
    },
    /// A sweep reads a left neuron twice (and therefore skips another).
    NeuronRepeated {
        /// Junction index (0 when checked in isolation).
        junction: usize,
        /// Sweep in which the repeat occurs.
        sweep: usize,
        /// The doubly-read left neuron.
        neuron: usize,
    },
    /// Two schedule slots map to the same (left, right) edge.
    DuplicateEdge {
        /// Junction index (0 when checked in isolation).
        junction: usize,
        /// Right (terminating) neuron of the duplicated edge.
        right: usize,
        /// Left (originating) neuron of the duplicated edge.
        left: usize,
    },
}

impl ClashError {
    /// Stamp the owning junction index (schedules are checked per
    /// junction; whole-network callers re-label).
    pub fn at_junction(mut self, j: usize) -> ClashError {
        match &mut self {
            ClashError::OutOfRange { junction, .. }
            | ClashError::MemoryClash { junction, .. }
            | ClashError::NeuronRepeated { junction, .. }
            | ClashError::DuplicateEdge { junction, .. } => *junction = j,
        }
        self
    }

    /// The junction the violation was stamped with.
    pub fn junction(&self) -> usize {
        match self {
            ClashError::OutOfRange { junction, .. }
            | ClashError::MemoryClash { junction, .. }
            | ClashError::NeuronRepeated { junction, .. }
            | ClashError::DuplicateEdge { junction, .. } => *junction,
        }
    }

    /// The counterexample cycle, where the violation has one.
    pub fn cycle(&self) -> Option<usize> {
        match self {
            ClashError::OutOfRange { cycle, .. } | ClashError::MemoryClash { cycle, .. } => {
                Some(*cycle)
            }
            _ => None,
        }
    }

    /// The counterexample memory (bank), where the violation has one.
    pub fn memory(&self) -> Option<usize> {
        match self {
            ClashError::OutOfRange { memory, .. } | ClashError::MemoryClash { memory, .. } => {
                Some(*memory)
            }
            _ => None,
        }
    }
}

impl std::fmt::Display for ClashError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClashError::OutOfRange { junction, cycle, memory, address } => write!(
                f,
                "junction {junction}, cycle {cycle}: access (memory {memory}, address {address}) outside the bank"
            ),
            ClashError::MemoryClash { junction, cycle, memory } => write!(
                f,
                "junction {junction}, cycle {cycle}: memory bank {memory} accessed twice (clash)"
            ),
            ClashError::NeuronRepeated { junction, sweep, neuron } => write!(
                f,
                "junction {junction}, sweep {sweep}: left neuron {neuron} read twice"
            ),
            ClashError::DuplicateEdge { junction, right, left } => write!(
                f,
                "junction {junction}: duplicate edge right {right} <- left {left}"
            ),
        }
    }
}

impl std::error::Error for ClashError {}

/// Clash-free pattern flavor (Appendix C types 1-3) with optional memory
/// dithering (per-sweep permutation of the z memories; type 1 keeps a
/// single permutation since its access pattern repeats every sweep).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Flavor {
    /// One seed vector, repeated with increments every sweep.
    Type1 {
        /// Apply per-pattern memory dithering.
        dither: bool,
    },
    /// Fresh seed vector per sweep.
    Type2 {
        /// Apply per-sweep memory dithering.
        dither: bool,
    },
    /// Fresh seed vector per cycle (largest pattern space).
    Type3 {
        /// Apply per-sweep memory dithering.
        dither: bool,
    },
}

impl Flavor {
    /// Display name, e.g. `type2+dither`.
    pub fn name(&self) -> String {
        let (t, d) = match self {
            Flavor::Type1 { dither } => (1, dither),
            Flavor::Type2 { dither } => (2, dither),
            Flavor::Type3 { dither } => (3, dither),
        };
        format!("type{}{}", t, if *d { "+dither" } else { "" })
    }
}

/// The per-cycle left-bank access schedule: `schedule[cycle][lane] =
/// (memory, address)`. This is what the hardware's address generators
/// emit, and what `hw::junction` replays against the banked memories.
pub struct AccessSchedule {
    /// Memories in the left bank (= edge processors fed per cycle).
    pub z: usize,
    /// Words per memory (`N_left / z`).
    pub depth: usize,
    /// `d_out` sweeps x `depth` cycles.
    pub cycles: Vec<Vec<(usize, usize)>>,
}

impl AccessSchedule {
    /// Left neuron read by `lane` in `cycle` under the Fig. 4 layout.
    pub fn neuron(&self, cycle: usize, lane: usize) -> usize {
        let (mem, addr) = self.cycles[cycle][lane];
        addr * self.z + mem
    }

    /// Verify the defining property by concrete replay: each memory
    /// accessed at most once per cycle, and within every sweep each memory
    /// visits every address exactly once (no neuron skipped or repeated in
    /// a sweep, Sec. III-B). [`ScheduleSpec::prove_clash_free`] decides the
    /// same property from the generator structure without this replay.
    pub fn verify_clash_free(&self) -> Result<(), ClashError> {
        for (t, lanes) in self.cycles.iter().enumerate() {
            let mut hit = vec![false; self.z];
            for &(mem, addr) in lanes {
                if mem >= self.z || addr >= self.depth {
                    return Err(ClashError::OutOfRange {
                        junction: 0,
                        cycle: t,
                        memory: mem,
                        address: addr,
                    });
                }
                if hit[mem] {
                    return Err(ClashError::MemoryClash { junction: 0, cycle: t, memory: mem });
                }
                hit[mem] = true;
            }
        }
        let sweeps = self.cycles.len() / self.depth;
        for s in 0..sweeps {
            let mut seen = vec![false; self.z * self.depth];
            for t in s * self.depth..(s + 1) * self.depth {
                for lane in 0..self.z {
                    let n = self.neuron(t, lane);
                    if seen[n] {
                        return Err(ClashError::NeuronRepeated { junction: 0, sweep: s, neuron: n });
                    }
                    seen[n] = true;
                }
            }
        }
        Ok(())
    }
}

/// Symbolic form of a left-bank access schedule: what the hardware's
/// address generators *store* (seed vectors, dither permutations, type-3
/// address columns) rather than the cycle-by-cycle accesses they emit.
/// Clash-freedom is decidable from this form alone
/// ([`Self::prove_clash_free`]); [`Self::materialize`] expands it to the
/// [`AccessSchedule`] the hardware replays.
#[derive(Clone, Debug)]
pub struct ScheduleSpec {
    /// Memories in the left bank (= edge processors fed per cycle).
    pub z: usize,
    /// Words per memory (`N_left / z`).
    pub depth: usize,
    /// One entry per sweep (`d_out` sweeps total).
    pub sweeps: Vec<SweepSpec>,
}

/// One sweep of a [`ScheduleSpec`]: a memory permutation plus an address
/// generator (Appendix C).
#[derive(Clone, Debug)]
pub struct SweepSpec {
    /// Lane -> memory map: the Appendix C dither permutation (identity
    /// when dithering is off).
    pub sigma: Vec<usize>,
    /// Per-lane address sequence.
    pub addr: AddrGen,
}

/// Address-generation structure of one sweep (Appendix C, Fig. 13).
#[derive(Clone, Debug)]
pub enum AddrGen {
    /// Types 1/2: `addr(lane, c) = (phi[lane] + c) mod depth` — a seed
    /// vector advanced by `z` incrementers.
    Affine {
        /// Seed address per lane (enters mod `depth`).
        phi: Vec<usize>,
    },
    /// Type 3: `addr(lane, c) = cols[lane][c]`, each column expected to
    /// be a permutation of `0..depth`.
    Explicit {
        /// Per-lane address columns.
        cols: Vec<Vec<usize>>,
    },
}

impl ScheduleSpec {
    /// Prove clash-freedom symbolically from the generator structure, in
    /// O(z * depth) per sweep and without materializing any cycle.
    ///
    /// Premises checked per sweep (the counterexample is synthesized from
    /// the first violated premise):
    /// 1. `sigma` is a permutation of `0..z`. Then within *every* cycle
    ///    the z lanes read z distinct memories — at most one access per
    ///    memory per cycle, for all cycles of the sweep at once.
    /// 2. Affine sweeps need nothing further: for a fixed lane the
    ///    addresses `(phi + c) mod depth` over `c = 0..depth` are a cyclic
    ///    rotation of `0..depth`, so each (memory, address) pair — each
    ///    left neuron — is read exactly once per sweep, whatever the seed.
    /// 3. Explicit sweeps: every column is a permutation of `0..depth`,
    ///    which states the same exactly-once guarantee directly.
    ///
    /// Together these give the Sec. III-B contract — no memory hit twice
    /// in a cycle, no neuron skipped or repeated in a sweep — and the
    /// verdict coincides with what [`AccessSchedule::verify_clash_free`]
    /// concludes by replaying [`Self::materialize`]'s output.
    pub fn prove_clash_free(&self) -> Result<(), ClashError> {
        for (s, sweep) in self.sweeps.iter().enumerate() {
            // first cycle of this sweep, for counterexample synthesis
            let base = s * self.depth;
            assert_eq!(sweep.sigma.len(), self.z, "sigma length != z");
            let mut seen_mem = vec![false; self.z];
            for &mem in &sweep.sigma {
                if mem >= self.z {
                    return Err(ClashError::OutOfRange {
                        junction: 0,
                        cycle: base,
                        memory: mem,
                        address: 0,
                    });
                }
                if seen_mem[mem] {
                    return Err(ClashError::MemoryClash { junction: 0, cycle: base, memory: mem });
                }
                seen_mem[mem] = true;
            }
            match &sweep.addr {
                AddrGen::Affine { phi } => {
                    assert_eq!(phi.len(), self.z, "phi length != z");
                }
                AddrGen::Explicit { cols } => {
                    assert_eq!(cols.len(), self.z, "column count != z");
                    for (lane, col) in cols.iter().enumerate() {
                        assert_eq!(col.len(), self.depth, "column length != depth");
                        let mem = sweep.sigma[lane];
                        let mut seen_addr = vec![false; self.depth];
                        for (c, &a) in col.iter().enumerate() {
                            if a >= self.depth {
                                return Err(ClashError::OutOfRange {
                                    junction: 0,
                                    cycle: base + c,
                                    memory: mem,
                                    address: a,
                                });
                            }
                            if seen_addr[a] {
                                return Err(ClashError::NeuronRepeated {
                                    junction: 0,
                                    sweep: s,
                                    neuron: a * self.z + mem,
                                });
                            }
                            seen_addr[a] = true;
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Expand to the concrete per-cycle [`AccessSchedule`] the hardware
    /// replays (and [`AccessSchedule::verify_clash_free`] brute-forces).
    pub fn materialize(&self) -> AccessSchedule {
        let mut cycles = Vec::with_capacity(self.sweeps.len() * self.depth);
        for sweep in &self.sweeps {
            for c in 0..self.depth {
                let row: Vec<(usize, usize)> = match &sweep.addr {
                    AddrGen::Affine { phi } => (0..self.z)
                        .map(|m| (sweep.sigma[m], (phi[m] + c) % self.depth))
                        .collect(),
                    AddrGen::Explicit { cols } => {
                        (0..self.z).map(|m| (sweep.sigma[m], cols[m][c])).collect()
                    }
                };
                cycles.push(row);
            }
        }
        AccessSchedule { z: self.z, depth: self.depth, cycles }
    }
}

/// Draw the symbolic address-generator state for a flavor. `d_out` =
/// number of sweeps. (Same RNG consumption order as the original direct
/// schedule construction, so seeded patterns are unchanged.)
pub fn schedule_spec(
    n_left: usize,
    z: usize,
    d_out: usize,
    flavor: Flavor,
    rng: &mut Rng,
) -> ScheduleSpec {
    assert!(z >= 1 && n_left % z == 0, "z must divide N_l (Appendix B)");
    let depth = n_left / z;
    let identity: Vec<usize> = (0..z).collect();
    let perm = |rng: &mut Rng| {
        let mut p: Vec<usize> = (0..z).collect();
        rng.shuffle(&mut p);
        p
    };
    let seed = |rng: &mut Rng| -> Vec<usize> { (0..z).map(|_| rng.below(depth)).collect() };
    let col_perm = |rng: &mut Rng| {
        let mut p: Vec<usize> = (0..depth).collect();
        rng.shuffle(&mut p);
        p
    };

    let sweeps: Vec<SweepSpec> = match flavor {
        Flavor::Type1 { dither } => {
            let phi = seed(rng);
            let sigma = if dither { perm(rng) } else { identity };
            vec![SweepSpec { sigma, addr: AddrGen::Affine { phi } }; d_out]
        }
        Flavor::Type2 { dither } => (0..d_out)
            .map(|_| {
                let phi = seed(rng);
                let sigma = if dither { perm(rng) } else { identity.clone() };
                SweepSpec { sigma, addr: AddrGen::Affine { phi } }
            })
            .collect(),
        Flavor::Type3 { dither } => (0..d_out)
            .map(|_| {
                let cols: Vec<Vec<usize>> = (0..z).map(|_| col_perm(rng)).collect();
                let sigma = if dither { perm(rng) } else { identity.clone() };
                SweepSpec { sigma, addr: AddrGen::Explicit { cols } }
            })
            .collect(),
    };
    ScheduleSpec { z, depth, sweeps }
}

/// Build the concrete access schedule for a flavor. `d_out` = number of
/// sweeps.
pub fn schedule(
    n_left: usize,
    z: usize,
    d_out: usize,
    flavor: Flavor,
    rng: &mut Rng,
) -> AccessSchedule {
    schedule_spec(n_left, z, d_out, flavor, rng).materialize()
}

/// Convert an access schedule into a connection pattern for a junction
/// with in-degree `d_in` (edge `e = t*z + m` terminates at right `e/d_in`).
pub fn pattern_from_schedule(
    shape: JunctionShape,
    d_in: usize,
    sched: &AccessSchedule,
) -> Result<Pattern, ClashError> {
    let n_edges = shape.n_right * d_in;
    assert_eq!(n_edges, sched.cycles.len() * sched.z, "schedule/edge count mismatch");
    let mut in_edges: Vec<Vec<u32>> = vec![Vec::with_capacity(d_in); shape.n_right];
    for t in 0..sched.cycles.len() {
        for m in 0..sched.z {
            let e = t * sched.z + m;
            let j = e / d_in;
            let n = sched.neuron(t, m);
            if in_edges[j].contains(&(n as u32)) {
                return Err(ClashError::DuplicateEdge { junction: 0, right: j, left: n });
            }
            in_edges[j].push(n as u32);
        }
    }
    Ok(Pattern { shape, in_edges })
}

/// Generate a clash-free pattern, retrying flavors that can produce
/// cross-sweep duplicate edges (types 2/3) until valid.
///
/// Clash-freedom of every draw is established by the symbolic prover
/// ([`ScheduleSpec::prove_clash_free`]) — an always-on O(edges) check
/// that, unlike the `debug_assert!` replay it replaces, still guards
/// release builds.
pub fn generate(
    shape: JunctionShape,
    d_out: usize,
    z: usize,
    flavor: Flavor,
    rng: &mut Rng,
) -> Pattern {
    assert_eq!(
        (shape.n_left * d_out) % shape.n_right,
        0,
        "d_in not integral (Appendix A)"
    );
    let d_in = shape.n_left * d_out / shape.n_right;
    for _attempt in 0..500 {
        let spec = schedule_spec(shape.n_left, z, d_out, flavor, rng);
        if let Err(e) = spec.prove_clash_free() {
            panic!("generated {} schedule is not clash-free: {e}", flavor.name());
        }
        let sched = spec.materialize();
        match pattern_from_schedule(shape, d_in, &sched) {
            Ok(p) => {
                if let Err(e) = p.audit() {
                    panic!("generated {} pattern failed audit: {e}", flavor.name());
                }
                return p;
            }
            // cross-sweep duplicate (possible for types 2/3): redraw
            Err(ClashError::DuplicateEdge { .. }) => {}
            Err(e) => panic!("schedule/pattern mismatch for {}: {e}", flavor.name()),
        }
    }
    panic!(
        "no duplicate-free {} pattern found for {shape:?} d_out={d_out} z={z} after 500 draws",
        flavor.name()
    );
}

/// A reasonable default degree of parallelism: the largest divisor of N_l
/// not exceeding N_l/4 (the paper picks z per hardware budget; Table II
/// uses e.g. z=200 for N_l=800).
pub fn default_z(shape: JunctionShape, _d_out: usize) -> usize {
    let n = shape.n_left;
    (1..=n / 4).rev().find(|d| n % d == 0).unwrap_or(n)
}

// ---------------------------------------------------------------------------
// Appendix C counting: |S_Mi| and address-generation storage (Table III).
// ---------------------------------------------------------------------------

/// Count of possible left-memory access patterns, carried in log10 (the
/// type-3 counts overflow u128 for real junctions); `exact` is the
/// integer-exact count, computed with checked u128 arithmetic and `None`
/// on overflow — never reconstructed from the float logarithm, which
/// loses integer precision above ~2^53.
#[derive(Clone, Copy, Debug)]
pub struct PatternSpace {
    /// log10 of the pattern count (always available).
    pub log10: f64,
    /// Integer-exact count, `None` on u128 overflow.
    pub exact: Option<u128>,
    /// false when the dither factor is only the (z!)^d_out upper bound
    /// (z and d_in mutually non-divisible, Appendix C).
    pub is_exact_formula: bool,
}

fn ln_factorial(n: usize) -> f64 {
    (2..=n).map(|k| (k as f64).ln()).sum()
}

fn log10_factorial(n: usize) -> f64 {
    ln_factorial(n) / std::f64::consts::LN_10
}

fn checked_factorial(n: usize) -> Option<u128> {
    let mut acc: u128 = 1;
    for k in 2..=n as u128 {
        acc = acc.checked_mul(k)?;
    }
    Some(acc)
}

fn checked_pow(base: u128, exp: usize) -> Option<u128> {
    let e: u32 = exp.try_into().ok()?;
    base.checked_pow(e)
}

/// Exact dither multiplier K_i (eq. 13) in checked u128; `None` on
/// overflow (the log10 path still carries the magnitude).
fn dither_factor_exact(z: usize, d_in: usize, d_out: usize, per_sweep: bool) -> Option<u128> {
    let expo = if per_sweep { d_out } else { 1 };
    if d_in % z == 0 {
        Some(1)
    } else if z % d_in == 0 {
        // K = (z! / (d_in!)^(z/d_in))^expo; the quotient is an exact
        // multinomial coefficient
        let denom = checked_pow(checked_factorial(d_in)?, z / d_in)?;
        checked_pow(checked_factorial(z)? / denom, expo)
    } else {
        // upper bound (z!)^expo
        checked_pow(checked_factorial(z)?, expo)
    }
}

/// Dither multiplier K_i (eq. 13). Returns (log10 K, exact formula?).
fn dither_factor(z: usize, d_in: usize, d_out: usize, per_sweep: bool) -> (f64, bool) {
    let expo = if per_sweep { d_out as f64 } else { 1.0 };
    if d_in % z == 0 {
        // integral d_in/z: a cycle touches all memories of one right neuron
        // group; dithering cannot change connectivity.
        (0.0, true)
    } else if z % d_in == 0 {
        // K = (z! / (d_in!)^(z/d_in))^expo
        let base = log10_factorial(z) - (z / d_in) as f64 * log10_factorial(d_in);
        (base * expo, true)
    } else {
        // upper bound (z!)^expo
        (log10_factorial(z) * expo, false)
    }
}

/// |S_Mi| for a junction (eqs. 10-12 plus the eq. 13 dither factor).
pub fn pattern_space(
    shape: JunctionShape,
    d_out: usize,
    z: usize,
    flavor: Flavor,
) -> PatternSpace {
    let depth = shape.n_left / z;
    let d_in = shape.n_left * d_out / shape.n_right;
    let (base_log10, base_exact, dith) = match flavor {
        Flavor::Type1 { dither } => (
            (z as f64) * (depth as f64).log10(),
            checked_pow(depth as u128, z),
            dither.then_some(false),
        ),
        Flavor::Type2 { dither } => (
            (z as f64) * (d_out as f64) * (depth as f64).log10(),
            checked_pow(depth as u128, z * d_out),
            dither.then_some(true),
        ),
        Flavor::Type3 { dither } => (
            (z as f64) * (d_out as f64) * log10_factorial(depth),
            checked_factorial(depth).and_then(|f| checked_pow(f, z * d_out)),
            dither.then_some(true),
        ),
    };
    let (k_log10, k_exact_formula, k_exact) = match dith {
        None => (0.0, true, Some(1u128)),
        Some(per_sweep) => {
            let (lg, ex) = dither_factor(z, d_in, d_out, per_sweep);
            (lg, ex, dither_factor_exact(z, d_in, d_out, per_sweep))
        }
    };
    // integer-exact count via checked u128 arithmetic; only the log10
    // carries the magnitude once any factor overflows
    let exact = match (base_exact, k_exact) {
        (Some(b), Some(k)) => b.checked_mul(k),
        _ => None,
    };
    PatternSpace {
        log10: base_log10 + k_log10,
        exact,
        is_exact_formula: k_exact_formula,
    }
}

/// Address-computation storage cost in words (Table III, last column).
pub fn address_storage_cost(shape: JunctionShape, d_out: usize, z: usize, flavor: Flavor) -> usize {
    match flavor {
        Flavor::Type1 { dither: false } => z,
        Flavor::Type1 { dither: true } => 2 * z,
        Flavor::Type2 { dither: false } => z * d_out,
        Flavor::Type2 { dither: true } => 2 * z * d_out,
        Flavor::Type3 { dither: false } => shape.n_left * d_out,
        Flavor::Type3 { dither: true } => (shape.n_left + z) * d_out,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const ALL_FLAVORS: [Flavor; 6] = [
        Flavor::Type1 { dither: false },
        Flavor::Type1 { dither: true },
        Flavor::Type2 { dither: false },
        Flavor::Type2 { dither: true },
        Flavor::Type3 { dither: false },
        Flavor::Type3 { dither: true },
    ];

    #[test]
    fn schedules_are_clash_free() {
        let mut rng = Rng::new(0);
        for flavor in ALL_FLAVORS {
            for (nl, z, dout) in [(12, 4, 2), (800, 200, 5), (39, 13, 3)] {
                let s = schedule(nl, z, dout, flavor, &mut rng);
                s.verify_clash_free()
                    .unwrap_or_else(|e| panic!("{} ({nl},{z},{dout}): {e}", flavor.name()));
                assert_eq!(s.cycles.len(), dout * nl / z);
            }
        }
    }

    #[test]
    fn generated_patterns_are_structured_and_valid() {
        let mut rng = Rng::new(1);
        for flavor in ALL_FLAVORS {
            let shape = JunctionShape { n_left: 60, n_right: 30 };
            let p = generate(shape, 6, 12, flavor, &mut rng);
            p.audit().unwrap();
            assert!(p.is_structured(), "{}", flavor.name());
            assert_eq!(p.n_edges(), 360);
            assert!(p.in_degrees().iter().all(|&d| d == 12));
            assert!(p.out_degrees().iter().all(|&d| d == 6));
        }
    }

    #[test]
    fn type1_never_needs_retry() {
        // Analytically: with one phi, any right neuron spans <= D consecutive
        // cycles, whose addresses are distinct per memory — no duplicates.
        let mut rng = Rng::new(2);
        for _ in 0..30 {
            let shape = JunctionShape { n_left: 100, n_right: 20 };
            let sched = schedule(100, 20, 7, Flavor::Type1 { dither: true }, &mut rng);
            assert!(pattern_from_schedule(shape, 35, &sched).is_ok());
        }
    }

    #[test]
    fn fig4_toy_schedule() {
        // Sec. III-C worked example: phi = (1,0,2,2), z=4, D=3 -> cycle 0
        // reads neurons (4,1,10,11), cycle 1 reads (8,5,2,3).
        let sched = AccessSchedule {
            z: 4,
            depth: 3,
            cycles: (0..6)
                .map(|t| {
                    let phi = [1usize, 0, 2, 2];
                    (0..4).map(|m| (m, (phi[m] + t) % 3)).collect()
                })
                .collect(),
        };
        sched.verify_clash_free().unwrap();
        assert_eq!((0..4).map(|m| sched.neuron(0, m)).collect::<Vec<_>>(), vec![4, 1, 10, 11]);
        assert_eq!((0..4).map(|m| sched.neuron(1, m)).collect::<Vec<_>>(), vec![8, 5, 2, 3]);
        // cycles 3-5 repeat cycles 0-2 (D = 3)
        assert_eq!(sched.neuron(3, 0), sched.neuron(0, 0));
    }

    #[test]
    fn prover_matches_replay_on_generated_specs() {
        let mut rng = Rng::new(3);
        for flavor in ALL_FLAVORS {
            for (nl, z, dout) in [(12, 4, 2), (24, 6, 3), (39, 13, 3)] {
                let spec = schedule_spec(nl, z, dout, flavor, &mut rng);
                spec.prove_clash_free()
                    .unwrap_or_else(|e| panic!("{} ({nl},{z},{dout}): {e}", flavor.name()));
                spec.materialize().verify_clash_free().unwrap();
            }
        }
    }

    #[test]
    fn prover_rejects_corrupted_sigma() {
        let mut rng = Rng::new(4);
        let mut spec = schedule_spec(24, 6, 2, Flavor::Type2 { dither: true }, &mut rng);
        // two lanes share a memory: a clash in every cycle of sweep 1
        spec.sweeps[1].sigma[0] = spec.sweeps[1].sigma[1];
        let err = spec.prove_clash_free().unwrap_err();
        assert!(matches!(err, ClashError::MemoryClash { .. }), "{err}");
        // counterexample points into sweep 1 and survives re-stamping
        assert_eq!(err.cycle(), Some(4));
        assert_eq!(err.at_junction(7).junction(), 7);
        // the replay agrees with the symbolic verdict
        assert!(spec.materialize().verify_clash_free().is_err());
    }

    #[test]
    fn prover_rejects_corrupted_column() {
        let mut rng = Rng::new(5);
        let mut spec = schedule_spec(12, 3, 2, Flavor::Type3 { dither: false }, &mut rng);
        if let AddrGen::Explicit { cols } = &mut spec.sweeps[0].addr {
            // lane 0 re-reads an address: a neuron repeat within sweep 0
            cols[0][1] = cols[0][0];
        } else {
            panic!("type 3 must carry explicit columns");
        }
        let err = spec.prove_clash_free().unwrap_err();
        assert!(matches!(err, ClashError::NeuronRepeated { sweep: 0, .. }), "{err}");
        assert!(spec.materialize().verify_clash_free().is_err());
    }

    #[test]
    fn typed_error_counterexample_fields() {
        let sched = AccessSchedule {
            z: 2,
            depth: 2,
            cycles: vec![vec![(0, 0), (0, 1)], vec![(0, 1), (1, 1)]],
        };
        match sched.verify_clash_free() {
            Err(ClashError::MemoryClash { junction: 0, cycle: 0, memory: 0 }) => {}
            other => panic!("want a memory clash at cycle 0 bank 0, got {other:?}"),
        }
    }

    #[test]
    fn table3_pattern_counts() {
        // Table III: (N_{i-1}, N_i, d_out, d_in, z) = (12, 12, 2, 2, 4).
        let shape = JunctionShape { n_left: 12, n_right: 12 };
        let cases: [(Flavor, u128); 6] = [
            (Flavor::Type1 { dither: false }, 81),
            (Flavor::Type1 { dither: true }, 486),
            (Flavor::Type2 { dither: false }, 6_561),
            (Flavor::Type2 { dither: true }, 236_196),
            (Flavor::Type3 { dither: false }, 1_679_616),
            (Flavor::Type3 { dither: true }, 60_466_176),
        ];
        for (flavor, want) in cases {
            let got = pattern_space(shape, 2, 4, flavor);
            // integer-exact counts, no float roundtrip
            assert_eq!(got.exact, Some(want), "{}", flavor.name());
            assert!(got.is_exact_formula);
        }
    }

    #[test]
    fn pattern_space_exact_beyond_f64_precision() {
        // depth^z = 3^40 = 12157665459056928801 (> 2^53): the old
        // 10^log10-roundtrip reconstruction loses the low digits here even
        // though the count fits comfortably in u128.
        let shape = JunctionShape { n_left: 120, n_right: 120 };
        let got = pattern_space(shape, 2, 40, Flavor::Type1 { dither: false });
        assert_eq!(got.exact, Some(3u128.pow(40)));
        assert!((got.log10 - 40.0 * 3f64.log10()).abs() < 1e-9);

        // type 2: depth^(z*d_out) = 3^80, still exact in u128
        let got2 = pattern_space(shape, 2, 40, Flavor::Type2 { dither: false });
        assert_eq!(got2.exact, Some(3u128.pow(80)));
    }

    #[test]
    fn pattern_space_overflow_falls_back_to_log10() {
        // the Table-II MNIST junction's type-3 space overflows u128 by a
        // huge margin; exact must be None with log10 still carrying the
        // magnitude
        let big = JunctionShape { n_left: 800, n_right: 100 };
        let got = pattern_space(big, 20, 200, Flavor::Type3 { dither: true });
        assert!(got.exact.is_none());
        assert!(got.log10 > 38.0);
    }

    #[test]
    fn checked_helpers() {
        assert_eq!(checked_factorial(0), Some(1));
        assert_eq!(checked_factorial(5), Some(120));
        assert_eq!(checked_factorial(34), Some((2..=34u128).product()));
        assert_eq!(checked_factorial(35), None, "35! overflows u128");
        assert_eq!(checked_pow(2, 127), Some(1u128 << 127));
        assert_eq!(checked_pow(2, 128), None);
        // exact dither factor agrees with the log-space one where defined
        let (lg, _) = dither_factor(4, 2, 2, true);
        assert_eq!(dither_factor_exact(4, 2, 2, true), Some(36));
        assert!((10f64.powf(lg) - 36.0).abs() < 1e-6);
        assert_eq!(dither_factor_exact(4, 8, 3, true), Some(1));
    }

    #[test]
    fn table3_storage_costs() {
        let shape = JunctionShape { n_left: 12, n_right: 12 };
        let costs: Vec<usize> = [
            Flavor::Type1 { dither: false },
            Flavor::Type1 { dither: true },
            Flavor::Type2 { dither: false },
            Flavor::Type2 { dither: true },
            Flavor::Type3 { dither: false },
            Flavor::Type3 { dither: true },
        ]
        .iter()
        .map(|f| address_storage_cost(shape, 2, 4, *f))
        .collect();
        assert_eq!(costs, vec![4, 8, 8, 16, 24, 32]);
    }

    #[test]
    fn dither_factor_cases() {
        // d_in % z == 0 -> no effect
        assert_eq!(dither_factor(4, 8, 3, true).0, 0.0);
        // z % d_in == 0, z/d_in = 2: K = 4!/(2!^2) = 6 per sweep
        let (lg, exact) = dither_factor(4, 2, 2, true);
        assert!(exact);
        assert!((10f64.powf(lg) - 36.0).abs() < 1e-6); // 6^2
        // mutually non-divisible -> upper bound flagged
        assert!(!dither_factor(4, 3, 2, true).1);
    }

    #[test]
    fn default_z_divides() {
        for nl in [800, 2000, 39, 100, 12] {
            let z = default_z(JunctionShape { n_left: nl, n_right: 10 }, 2);
            assert_eq!(nl % z, 0, "nl={nl} z={z}");
        }
    }
}
