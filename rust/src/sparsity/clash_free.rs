//! Clash-free connection patterns (Sec. III-C, Appendix C).
//!
//! Left-bank storage layout (Fig. 2b / Fig. 4): left neuron `n` lives in
//! memory `n % z` at address `n / z`; the bank has `z` memories of depth
//! `D = N_l / z`. A pattern is defined by the address each of the `z`
//! lanes reads every cycle; one element per memory per cycle = clash-free
//! by construction. Edges are numbered sequentially by right neuron
//! (Sec. III-B): cycle `t` processes edges `[t*z, (t+1)*z)`, lane `m`
//! carries edge `t*z + m`, and edge `e` terminates at right neuron
//! `e / d_in`.
//!
//! Three flavors (Appendix C, Fig. 13), each optionally memory-dithered:
//! - Type 1: one seed vector `phi`, addresses advance cyclically
//!   (`addr = (phi[m] + c) mod D`), identical every sweep. Hardware cost:
//!   store `phi`, use `z` incrementers.
//! - Type 2: a fresh seed vector per sweep (our earlier FPGA work [40]).
//! - Type 3: an arbitrary per-sweep address matrix `Phi in {0..D-1}^{D x z}`
//!   whose columns are permutations (full access-sequence storage).

use super::config::JunctionShape;
use super::pattern::Pattern;
use crate::util::rng::Rng;

/// Clash-free pattern flavor (Appendix C types 1-3) with optional memory
/// dithering (per-sweep permutation of the z memories; type 1 keeps a
/// single permutation since its access pattern repeats every sweep).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Flavor {
    /// One seed vector, repeated with increments every sweep.
    Type1 {
        /// Apply per-pattern memory dithering.
        dither: bool,
    },
    /// Fresh seed vector per sweep.
    Type2 {
        /// Apply per-sweep memory dithering.
        dither: bool,
    },
    /// Fresh seed vector per cycle (largest pattern space).
    Type3 {
        /// Apply per-sweep memory dithering.
        dither: bool,
    },
}

impl Flavor {
    /// Display name, e.g. `type2+dither`.
    pub fn name(&self) -> String {
        let (t, d) = match self {
            Flavor::Type1 { dither } => (1, dither),
            Flavor::Type2 { dither } => (2, dither),
            Flavor::Type3 { dither } => (3, dither),
        };
        format!("type{}{}", t, if *d { "+dither" } else { "" })
    }
}

/// The per-cycle left-bank access schedule: `schedule[cycle][lane] =
/// (memory, address)`. This is what the hardware's address generators
/// emit, and what `hw::junction` replays against the banked memories.
pub struct AccessSchedule {
    /// Memories in the left bank (= edge processors fed per cycle).
    pub z: usize,
    /// Words per memory (`N_left / z`).
    pub depth: usize,
    /// `d_out` sweeps x `depth` cycles.
    pub cycles: Vec<Vec<(usize, usize)>>,
}

impl AccessSchedule {
    /// Left neuron read by `lane` in `cycle` under the Fig. 4 layout.
    pub fn neuron(&self, cycle: usize, lane: usize) -> usize {
        let (mem, addr) = self.cycles[cycle][lane];
        addr * self.z + mem
    }

    /// Verify the defining property: each memory accessed at most once per
    /// cycle, and within every sweep each memory visits every address
    /// exactly once (no neuron skipped or repeated in a sweep, Sec. III-B).
    pub fn verify_clash_free(&self) -> Result<(), String> {
        for (t, lanes) in self.cycles.iter().enumerate() {
            let mut hit = vec![false; self.z];
            for &(mem, addr) in lanes {
                if mem >= self.z || addr >= self.depth {
                    return Err(format!("cycle {t}: access ({mem},{addr}) out of range"));
                }
                if hit[mem] {
                    return Err(format!("cycle {t}: memory {mem} accessed twice (clash)"));
                }
                hit[mem] = true;
            }
        }
        let sweeps = self.cycles.len() / self.depth;
        for s in 0..sweeps {
            let mut seen = vec![false; self.z * self.depth];
            for t in s * self.depth..(s + 1) * self.depth {
                for lane in 0..self.z {
                    let n = self.neuron(t, lane);
                    if seen[n] {
                        return Err(format!("sweep {s}: neuron {n} read twice"));
                    }
                    seen[n] = true;
                }
            }
        }
        Ok(())
    }
}

/// Build the access schedule for a flavor. `d_out` = number of sweeps.
pub fn schedule(
    n_left: usize,
    z: usize,
    d_out: usize,
    flavor: Flavor,
    rng: &mut Rng,
) -> AccessSchedule {
    assert!(z >= 1 && n_left % z == 0, "z must divide N_l (Appendix B)");
    let depth = n_left / z;
    let identity: Vec<usize> = (0..z).collect();
    let perm = |rng: &mut Rng| {
        let mut p: Vec<usize> = (0..z).collect();
        rng.shuffle(&mut p);
        p
    };
    let seed = |rng: &mut Rng| -> Vec<usize> { (0..z).map(|_| rng.below(depth)).collect() };
    let col_perm = |rng: &mut Rng| {
        let mut p: Vec<usize> = (0..depth).collect();
        rng.shuffle(&mut p);
        p
    };

    let mut cycles = Vec::with_capacity(d_out * depth);
    match flavor {
        Flavor::Type1 { dither } => {
            let phi = seed(rng);
            let sigma = if dither { perm(rng) } else { identity.clone() };
            for _sweep in 0..d_out {
                for c in 0..depth {
                    cycles.push(
                        (0..z)
                            .map(|m| (sigma[m], (phi[m] + c) % depth))
                            .collect(),
                    );
                }
            }
        }
        Flavor::Type2 { dither } => {
            for _sweep in 0..d_out {
                let phi = seed(rng);
                let sigma = if dither { perm(rng) } else { identity.clone() };
                for c in 0..depth {
                    cycles.push(
                        (0..z)
                            .map(|m| (sigma[m], (phi[m] + c) % depth))
                            .collect(),
                    );
                }
            }
        }
        Flavor::Type3 { dither } => {
            for _sweep in 0..d_out {
                let cols: Vec<Vec<usize>> = (0..z).map(|_| col_perm(rng)).collect();
                let sigma = if dither { perm(rng) } else { identity.clone() };
                for c in 0..depth {
                    cycles.push((0..z).map(|m| (sigma[m], cols[m][c])).collect());
                }
            }
        }
    }
    AccessSchedule { z, depth, cycles }
}

/// Convert an access schedule into a connection pattern for a junction
/// with in-degree `d_in` (edge `e = t*z + m` terminates at right `e/d_in`).
pub fn pattern_from_schedule(
    shape: JunctionShape,
    d_in: usize,
    sched: &AccessSchedule,
) -> Result<Pattern, String> {
    let n_edges = shape.n_right * d_in;
    assert_eq!(n_edges, sched.cycles.len() * sched.z, "schedule/edge count mismatch");
    let mut in_edges: Vec<Vec<u32>> = vec![Vec::with_capacity(d_in); shape.n_right];
    for t in 0..sched.cycles.len() {
        for m in 0..sched.z {
            let e = t * sched.z + m;
            let j = e / d_in;
            let n = sched.neuron(t, m);
            if in_edges[j].contains(&(n as u32)) {
                return Err(format!("duplicate edge: right {j} <- left {n}"));
            }
            in_edges[j].push(n as u32);
        }
    }
    Ok(Pattern { shape, in_edges })
}

/// Generate a clash-free pattern, retrying flavors that can produce
/// cross-sweep duplicate edges (types 2/3) until valid.
pub fn generate(
    shape: JunctionShape,
    d_out: usize,
    z: usize,
    flavor: Flavor,
    rng: &mut Rng,
) -> Pattern {
    assert_eq!(
        (shape.n_left * d_out) % shape.n_right,
        0,
        "d_in not integral (Appendix A)"
    );
    let d_in = shape.n_left * d_out / shape.n_right;
    for _attempt in 0..500 {
        let sched = schedule(shape.n_left, z, d_out, flavor, rng);
        debug_assert!(sched.verify_clash_free().is_ok());
        if let Ok(p) = pattern_from_schedule(shape, d_in, &sched) {
            debug_assert!(p.audit().is_ok());
            return p;
        }
    }
    panic!(
        "no duplicate-free {} pattern found for {shape:?} d_out={d_out} z={z} after 500 draws",
        flavor.name()
    );
}

/// A reasonable default degree of parallelism: the largest divisor of N_l
/// not exceeding N_l/4 (the paper picks z per hardware budget; Table II
/// uses e.g. z=200 for N_l=800).
pub fn default_z(shape: JunctionShape, _d_out: usize) -> usize {
    let n = shape.n_left;
    (1..=n / 4).rev().find(|d| n % d == 0).unwrap_or(n)
}

// ---------------------------------------------------------------------------
// Appendix C counting: |S_Mi| and address-generation storage (Table III).
// ---------------------------------------------------------------------------

/// Count of possible left-memory access patterns, carried in log10 (the
/// type-3 counts overflow u128 for real junctions); `exact` is the
/// integer-exact count, computed with checked u128 arithmetic and `None`
/// on overflow — never reconstructed from the float logarithm, which
/// loses integer precision above ~2^53.
#[derive(Clone, Copy, Debug)]
pub struct PatternSpace {
    /// log10 of the pattern count (always available).
    pub log10: f64,
    /// Integer-exact count, `None` on u128 overflow.
    pub exact: Option<u128>,
    /// false when the dither factor is only the (z!)^d_out upper bound
    /// (z and d_in mutually non-divisible, Appendix C).
    pub is_exact_formula: bool,
}

fn ln_factorial(n: usize) -> f64 {
    (2..=n).map(|k| (k as f64).ln()).sum()
}

fn log10_factorial(n: usize) -> f64 {
    ln_factorial(n) / std::f64::consts::LN_10
}

fn checked_factorial(n: usize) -> Option<u128> {
    let mut acc: u128 = 1;
    for k in 2..=n as u128 {
        acc = acc.checked_mul(k)?;
    }
    Some(acc)
}

fn checked_pow(base: u128, exp: usize) -> Option<u128> {
    let e: u32 = exp.try_into().ok()?;
    base.checked_pow(e)
}

/// Exact dither multiplier K_i (eq. 13) in checked u128; `None` on
/// overflow (the log10 path still carries the magnitude).
fn dither_factor_exact(z: usize, d_in: usize, d_out: usize, per_sweep: bool) -> Option<u128> {
    let expo = if per_sweep { d_out } else { 1 };
    if d_in % z == 0 {
        Some(1)
    } else if z % d_in == 0 {
        // K = (z! / (d_in!)^(z/d_in))^expo; the quotient is an exact
        // multinomial coefficient
        let denom = checked_pow(checked_factorial(d_in)?, z / d_in)?;
        checked_pow(checked_factorial(z)? / denom, expo)
    } else {
        // upper bound (z!)^expo
        checked_pow(checked_factorial(z)?, expo)
    }
}

/// Dither multiplier K_i (eq. 13). Returns (log10 K, exact formula?).
fn dither_factor(z: usize, d_in: usize, d_out: usize, per_sweep: bool) -> (f64, bool) {
    let expo = if per_sweep { d_out as f64 } else { 1.0 };
    if d_in % z == 0 {
        // integral d_in/z: a cycle touches all memories of one right neuron
        // group; dithering cannot change connectivity.
        (0.0, true)
    } else if z % d_in == 0 {
        // K = (z! / (d_in!)^(z/d_in))^expo
        let base = log10_factorial(z) - (z / d_in) as f64 * log10_factorial(d_in);
        (base * expo, true)
    } else {
        // upper bound (z!)^expo
        (log10_factorial(z) * expo, false)
    }
}

/// |S_Mi| for a junction (eqs. 10-12 plus the eq. 13 dither factor).
pub fn pattern_space(
    shape: JunctionShape,
    d_out: usize,
    z: usize,
    flavor: Flavor,
) -> PatternSpace {
    let depth = shape.n_left / z;
    let d_in = shape.n_left * d_out / shape.n_right;
    let (base_log10, base_exact, dith) = match flavor {
        Flavor::Type1 { dither } => (
            (z as f64) * (depth as f64).log10(),
            checked_pow(depth as u128, z),
            dither.then_some(false),
        ),
        Flavor::Type2 { dither } => (
            (z as f64) * (d_out as f64) * (depth as f64).log10(),
            checked_pow(depth as u128, z * d_out),
            dither.then_some(true),
        ),
        Flavor::Type3 { dither } => (
            (z as f64) * (d_out as f64) * log10_factorial(depth),
            checked_factorial(depth).and_then(|f| checked_pow(f, z * d_out)),
            dither.then_some(true),
        ),
    };
    let (k_log10, k_exact_formula, k_exact) = match dith {
        None => (0.0, true, Some(1u128)),
        Some(per_sweep) => {
            let (lg, ex) = dither_factor(z, d_in, d_out, per_sweep);
            (lg, ex, dither_factor_exact(z, d_in, d_out, per_sweep))
        }
    };
    // integer-exact count via checked u128 arithmetic; only the log10
    // carries the magnitude once any factor overflows
    let exact = match (base_exact, k_exact) {
        (Some(b), Some(k)) => b.checked_mul(k),
        _ => None,
    };
    PatternSpace {
        log10: base_log10 + k_log10,
        exact,
        is_exact_formula: k_exact_formula,
    }
}

/// Address-computation storage cost in words (Table III, last column).
pub fn address_storage_cost(shape: JunctionShape, d_out: usize, z: usize, flavor: Flavor) -> usize {
    match flavor {
        Flavor::Type1 { dither: false } => z,
        Flavor::Type1 { dither: true } => 2 * z,
        Flavor::Type2 { dither: false } => z * d_out,
        Flavor::Type2 { dither: true } => 2 * z * d_out,
        Flavor::Type3 { dither: false } => shape.n_left * d_out,
        Flavor::Type3 { dither: true } => (shape.n_left + z) * d_out,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const ALL_FLAVORS: [Flavor; 6] = [
        Flavor::Type1 { dither: false },
        Flavor::Type1 { dither: true },
        Flavor::Type2 { dither: false },
        Flavor::Type2 { dither: true },
        Flavor::Type3 { dither: false },
        Flavor::Type3 { dither: true },
    ];

    #[test]
    fn schedules_are_clash_free() {
        let mut rng = Rng::new(0);
        for flavor in ALL_FLAVORS {
            for (nl, z, dout) in [(12, 4, 2), (800, 200, 5), (39, 13, 3)] {
                let s = schedule(nl, z, dout, flavor, &mut rng);
                s.verify_clash_free()
                    .unwrap_or_else(|e| panic!("{} ({nl},{z},{dout}): {e}", flavor.name()));
                assert_eq!(s.cycles.len(), dout * nl / z);
            }
        }
    }

    #[test]
    fn generated_patterns_are_structured_and_valid() {
        let mut rng = Rng::new(1);
        for flavor in ALL_FLAVORS {
            let shape = JunctionShape { n_left: 60, n_right: 30 };
            let p = generate(shape, 6, 12, flavor, &mut rng);
            p.audit().unwrap();
            assert!(p.is_structured(), "{}", flavor.name());
            assert_eq!(p.n_edges(), 360);
            assert!(p.in_degrees().iter().all(|&d| d == 12));
            assert!(p.out_degrees().iter().all(|&d| d == 6));
        }
    }

    #[test]
    fn type1_never_needs_retry() {
        // Analytically: with one phi, any right neuron spans <= D consecutive
        // cycles, whose addresses are distinct per memory — no duplicates.
        let mut rng = Rng::new(2);
        for _ in 0..30 {
            let shape = JunctionShape { n_left: 100, n_right: 20 };
            let sched = schedule(100, 20, 7, Flavor::Type1 { dither: true }, &mut rng);
            assert!(pattern_from_schedule(shape, 35, &sched).is_ok());
        }
    }

    #[test]
    fn fig4_toy_schedule() {
        // Sec. III-C worked example: phi = (1,0,2,2), z=4, D=3 -> cycle 0
        // reads neurons (4,1,10,11), cycle 1 reads (8,5,2,3).
        let sched = AccessSchedule {
            z: 4,
            depth: 3,
            cycles: (0..6)
                .map(|t| {
                    let phi = [1usize, 0, 2, 2];
                    (0..4).map(|m| (m, (phi[m] + t) % 3)).collect()
                })
                .collect(),
        };
        sched.verify_clash_free().unwrap();
        assert_eq!((0..4).map(|m| sched.neuron(0, m)).collect::<Vec<_>>(), vec![4, 1, 10, 11]);
        assert_eq!((0..4).map(|m| sched.neuron(1, m)).collect::<Vec<_>>(), vec![8, 5, 2, 3]);
        // cycles 3-5 repeat cycles 0-2 (D = 3)
        assert_eq!(sched.neuron(3, 0), sched.neuron(0, 0));
    }

    #[test]
    fn table3_pattern_counts() {
        // Table III: (N_{i-1}, N_i, d_out, d_in, z) = (12, 12, 2, 2, 4).
        let shape = JunctionShape { n_left: 12, n_right: 12 };
        let cases: [(Flavor, u128); 6] = [
            (Flavor::Type1 { dither: false }, 81),
            (Flavor::Type1 { dither: true }, 486),
            (Flavor::Type2 { dither: false }, 6_561),
            (Flavor::Type2 { dither: true }, 236_196),
            (Flavor::Type3 { dither: false }, 1_679_616),
            (Flavor::Type3 { dither: true }, 60_466_176),
        ];
        for (flavor, want) in cases {
            let got = pattern_space(shape, 2, 4, flavor);
            // integer-exact counts, no float roundtrip
            assert_eq!(got.exact, Some(want), "{}", flavor.name());
            assert!(got.is_exact_formula);
        }
    }

    #[test]
    fn pattern_space_exact_beyond_f64_precision() {
        // depth^z = 3^40 = 12157665459056928801 (> 2^53): the old
        // 10^log10-roundtrip reconstruction loses the low digits here even
        // though the count fits comfortably in u128.
        let shape = JunctionShape { n_left: 120, n_right: 120 };
        let got = pattern_space(shape, 2, 40, Flavor::Type1 { dither: false });
        assert_eq!(got.exact, Some(3u128.pow(40)));
        assert!((got.log10 - 40.0 * 3f64.log10()).abs() < 1e-9);

        // type 2: depth^(z*d_out) = 3^80, still exact in u128
        let got2 = pattern_space(shape, 2, 40, Flavor::Type2 { dither: false });
        assert_eq!(got2.exact, Some(3u128.pow(80)));
    }

    #[test]
    fn pattern_space_overflow_falls_back_to_log10() {
        // the Table-II MNIST junction's type-3 space overflows u128 by a
        // huge margin; exact must be None with log10 still carrying the
        // magnitude
        let big = JunctionShape { n_left: 800, n_right: 100 };
        let got = pattern_space(big, 20, 200, Flavor::Type3 { dither: true });
        assert!(got.exact.is_none());
        assert!(got.log10 > 38.0);
    }

    #[test]
    fn checked_helpers() {
        assert_eq!(checked_factorial(0), Some(1));
        assert_eq!(checked_factorial(5), Some(120));
        assert_eq!(checked_factorial(34), Some((2..=34u128).product()));
        assert_eq!(checked_factorial(35), None, "35! overflows u128");
        assert_eq!(checked_pow(2, 127), Some(1u128 << 127));
        assert_eq!(checked_pow(2, 128), None);
        // exact dither factor agrees with the log-space one where defined
        let (lg, _) = dither_factor(4, 2, 2, true);
        assert_eq!(dither_factor_exact(4, 2, 2, true), Some(36));
        assert!((10f64.powf(lg) - 36.0).abs() < 1e-6);
        assert_eq!(dither_factor_exact(4, 8, 3, true), Some(1));
    }

    #[test]
    fn table3_storage_costs() {
        let shape = JunctionShape { n_left: 12, n_right: 12 };
        let costs: Vec<usize> = [
            Flavor::Type1 { dither: false },
            Flavor::Type1 { dither: true },
            Flavor::Type2 { dither: false },
            Flavor::Type2 { dither: true },
            Flavor::Type3 { dither: false },
            Flavor::Type3 { dither: true },
        ]
        .iter()
        .map(|f| address_storage_cost(shape, 2, 4, *f))
        .collect();
        assert_eq!(costs, vec![4, 8, 8, 16, 24, 32]);
    }

    #[test]
    fn dither_factor_cases() {
        // d_in % z == 0 -> no effect
        assert_eq!(dither_factor(4, 8, 3, true).0, 0.0);
        // z % d_in == 0, z/d_in = 2: K = 4!/(2!^2) = 6 per sweep
        let (lg, exact) = dither_factor(4, 2, 2, true);
        assert!(exact);
        assert!((10f64.powf(lg) - 36.0).abs() < 1e-6); // 6^2
        // mutually non-divisible -> upper bound flagged
        assert!(!dither_factor(4, 3, 2, true).1);
    }

    #[test]
    fn default_z_divides() {
        for nl in [800, 2000, 39, 100, 12] {
            let z = default_z(JunctionShape { n_left: nl, n_right: 10 }, 2);
            assert_eq!(nl % z, 0, "nl={nl} z={z}");
        }
    }
}
