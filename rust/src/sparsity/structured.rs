//! Structured pre-defined sparsity (Sec. II-A): random patterns with fixed
//! out-degree d_out on every left neuron and fixed in-degree d_in on every
//! right neuron.
//!
//! Generation is the bipartite configuration model with repair: deal each
//! left neuron's d_out stubs across the right neurons' d_in slots, then fix
//! duplicate (right, left) pairs by swapping with other rows; a bounded
//! number of full reshuffles guards pathological deals.

use super::config::JunctionShape;
use super::pattern::Pattern;
use crate::util::rng::Rng;

/// Generate a structured pattern. Panics if (shape, d_out) violates the
/// Appendix-A integrality constraint.
pub fn generate(shape: JunctionShape, d_out: usize, rng: &mut Rng) -> Pattern {
    assert!(d_out >= 1 && d_out <= shape.n_right, "d_out out of range");
    assert_eq!(
        (shape.n_left * d_out) % shape.n_right,
        0,
        "d_in = {}*{}/{} not integral (Appendix A)",
        shape.n_left,
        d_out,
        shape.n_right
    );
    let d_in = shape.n_left * d_out / shape.n_right;
    if d_in == shape.n_left {
        // FC junction: exactly one pattern exists.
        return Pattern::fully_connected(shape);
    }

    for _attempt in 0..64 {
        // stubs: each left neuron repeated d_out times
        let mut stubs: Vec<u32> = (0..shape.n_left as u32)
            .flat_map(|k| std::iter::repeat(k).take(d_out))
            .collect();
        rng.shuffle(&mut stubs);
        if let Some(rows) = deal_and_repair(&stubs, shape.n_right, d_in, rng) {
            let p = Pattern {
                shape,
                in_edges: rows,
            };
            debug_assert!(p.audit().is_ok());
            return p;
        }
    }
    panic!("structured pattern generation failed after 64 reshuffles (shape {shape:?}, d_out {d_out})");
}

/// Split `stubs` into `n_right` rows of `d_in`, then repair duplicate
/// entries within a row by swapping with entries from other rows.
fn deal_and_repair(
    stubs: &[u32],
    n_right: usize,
    d_in: usize,
    rng: &mut Rng,
) -> Option<Vec<Vec<u32>>> {
    let mut rows: Vec<Vec<u32>> = stubs.chunks(d_in).map(|c| c.to_vec()).collect();
    debug_assert_eq!(rows.len(), n_right);

    let nl = 1 + *stubs.iter().max().unwrap() as usize;

    for j in 0..n_right {
        while let Some(pos) = first_dup_pos(&rows[j]) {
            // Deterministic repair: row j is missing some value b (it has a
            // duplicate a, so by pigeonhole at least one value in 0..nl is
            // absent... but b must come from another row to preserve
            // out-degrees). Find a row j2 holding some b not in row j, where
            // row j2 (minus that slot) does not hold a, and swap.
            let a = rows[j][pos];
            let mut in_j = vec![false; nl];
            for &x in &rows[j] {
                in_j[x as usize] = true;
            }
            let start = rng.below(n_right);
            let mut fixed = false;
            'search: for off in 0..n_right {
                let j2 = (start + off) % n_right;
                if j2 == j {
                    continue;
                }
                let count_a = rows[j2].iter().filter(|&&x| x == a).count();
                for p2 in 0..d_in {
                    let b = rows[j2][p2];
                    if b == a || in_j[b as usize] {
                        continue;
                    }
                    // after swap, row j2 holds `a` at p2: ok iff it had no
                    // other copy of a
                    if count_a == 0 {
                        rows[j][pos] = b;
                        rows[j2][p2] = a;
                        fixed = true;
                        break 'search;
                    }
                }
            }
            if !fixed {
                return None; // pathological deal; caller reshuffles
            }
        }
    }
    debug_assert!(rows.iter().all(|r| first_dup_pos(r).is_none()));
    Some(rows)
}

fn first_dup_pos(row: &[u32]) -> Option<usize> {
    for (i, &x) in row.iter().enumerate() {
        if row[..i].contains(&x) {
            return Some(i);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn degrees_are_exact() {
        let mut rng = Rng::new(0);
        for (nl, nr, dout) in [(12, 8, 2), (800, 100, 20), (100, 10, 10), (39, 390, 90)] {
            let shape = JunctionShape { n_left: nl, n_right: nr };
            let p = generate(shape, dout, &mut rng);
            p.audit().unwrap();
            assert!(p.is_structured(), "({nl},{nr},{dout})");
            assert!(p.out_degrees().iter().all(|&d| d == dout));
            let din = nl * dout / nr;
            assert!(p.in_degrees().iter().all(|&d| d == din));
        }
    }

    #[test]
    fn fc_case() {
        let mut rng = Rng::new(1);
        let shape = JunctionShape { n_left: 6, n_right: 4 };
        let p = generate(shape, 4, &mut rng);
        assert!((p.density() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn extreme_sparsity_has_no_disconnection() {
        // structured d_out >= 1 guarantees every left neuron connected,
        // d_in >= 1 every right neuron connected — the Sec. IV-B advantage.
        let mut rng = Rng::new(2);
        let shape = JunctionShape { n_left: 2000, n_right: 50 };
        let p = generate(shape, 1, &mut rng);
        assert_eq!(p.disconnected_left(), 0);
        assert_eq!(p.disconnected_right(), 0);
        assert_eq!(p.n_edges(), 2000);
    }

    #[test]
    fn different_seeds_differ() {
        let shape = JunctionShape { n_left: 40, n_right: 20 };
        let a = generate(shape, 5, &mut Rng::new(3));
        let b = generate(shape, 5, &mut Rng::new(4));
        assert_ne!(a.in_edges, b.in_edges);
    }

    #[test]
    #[should_panic(expected = "not integral")]
    fn rejects_invalid_dout() {
        generate(JunctionShape { n_left: 117, n_right: 390 }, 5, &mut Rng::new(0));
    }
}
