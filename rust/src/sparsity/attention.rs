//! Attention-based preprocessed sparsity (Sec. V-A): quantize input-feature
//! variance into three attention levels and give high-variance inputs more
//! out-connections; later junctions stay uniform.

use super::config::JunctionShape;
use super::pattern::Pattern;
use crate::util::rng::Rng;

/// Out-degree per input neuron from feature variances: variances are
/// quantized into three levels by terciles; levels get weights (w, 2w, 3w)
/// scaled so total edges ~= n_left * base_dout, each clamped to
/// [1, n_right].
pub fn variance_out_degrees(variances: &[f32], base_dout: usize, n_right: usize) -> Vec<usize> {
    let n = variances.len();
    assert!(n > 0 && base_dout >= 1);
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| variances[a].total_cmp(&variances[b]));
    // tercile level per neuron: 1 (low), 2 (mid), 3 (high attention)
    let mut level = vec![1usize; n];
    for (rank, &i) in order.iter().enumerate() {
        level[i] = 1 + (rank * 3) / n;
    }
    let level_sum: usize = level.iter().sum();
    let target_edges = n * base_dout;
    let mut d: Vec<usize> = level
        .iter()
        .map(|&l| ((l * target_edges) as f64 / level_sum as f64).round().max(1.0) as usize)
        .map(|d| d.clamp(1, n_right))
        .collect();
    // nudge to hit the exact edge budget (keeps comparisons density-matched)
    let mut total: isize = d.iter().sum::<usize>() as isize;
    let want = target_edges as isize;
    let mut rank_iter_up = order.iter().rev().cycle();
    let mut rank_iter_down = order.iter().cycle();
    while total < want {
        let &i = rank_iter_up.next().unwrap();
        if d[i] < n_right {
            d[i] += 1;
            total += 1;
        }
    }
    while total > want {
        let &i = rank_iter_down.next().unwrap();
        if d[i] > 1 {
            d[i] -= 1;
            total -= 1;
        }
    }
    d
}

/// Build a pattern with the given per-left-neuron out-degrees: each left
/// neuron's stubs are dealt to right neurons, keeping in-degrees balanced
/// (right neurons filled in random order of current in-degree).
pub fn generate_with_out_degrees(
    shape: JunctionShape,
    out_degrees: &[usize],
    rng: &mut Rng,
) -> Pattern {
    assert_eq!(out_degrees.len(), shape.n_left);
    let mut in_edges: Vec<Vec<u32>> = vec![Vec::new(); shape.n_right];
    // process left neurons in random order; for each, connect to the
    // out_degree right neurons with the lowest current in-degree (ties
    // broken randomly) that it is not already connected to.
    let mut left_order: Vec<usize> = (0..shape.n_left).collect();
    rng.shuffle(&mut left_order);
    for &k in &left_order {
        let dk = out_degrees[k].min(shape.n_right);
        let mut cand: Vec<usize> = (0..shape.n_right).collect();
        rng.shuffle(&mut cand);
        cand.sort_by_key(|&j| in_edges[j].len());
        let mut placed = 0;
        for &j in &cand {
            if placed == dk {
                break;
            }
            if !in_edges[j].contains(&(k as u32)) {
                in_edges[j].push(k as u32);
                placed += 1;
            }
        }
        assert_eq!(placed, dk, "could not place left neuron {k}");
    }
    Pattern { shape, in_edges }
}

/// Full §V-A pattern for a network: attention-weighted first junction,
/// structured uniform for the rest.
pub fn generate_net(
    net: &super::config::NetConfig,
    dout: &super::config::DoutConfig,
    feature_variances: &[f32],
    rng: &mut Rng,
) -> super::pattern::NetPattern {
    let mut junctions = Vec::new();
    for i in 0..net.n_junctions() {
        let shape = net.junction(i);
        if i == 0 {
            let d = variance_out_degrees(feature_variances, dout.0[0], shape.n_right);
            junctions.push(generate_with_out_degrees(shape, &d, rng));
        } else {
            junctions.push(super::structured::generate(shape, dout.0[i], rng));
        }
    }
    super::pattern::NetPattern { junctions }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::config::{DoutConfig, NetConfig};

    #[test]
    fn high_variance_features_get_more_edges() {
        let mut var = vec![0.1f32; 30];
        for v in var.iter_mut().take(10) {
            *v = 10.0;
        }
        let d = variance_out_degrees(&var, 4, 50);
        let high: usize = d[..10].iter().sum();
        let low: usize = d[10..20].iter().sum();
        assert!(high > low, "high {high} low {low}");
        assert_eq!(d.iter().sum::<usize>(), 30 * 4);
    }

    #[test]
    fn edge_budget_is_exact() {
        let mut rng = Rng::new(0);
        let var: Vec<f32> = (0..100).map(|_| rng.uniform()).collect();
        let d = variance_out_degrees(&var, 7, 40);
        assert_eq!(d.iter().sum::<usize>(), 700);
        assert!(d.iter().all(|&x| (1..=40).contains(&x)));
    }

    #[test]
    fn generated_pattern_valid_with_balanced_in_degree() {
        let mut rng = Rng::new(1);
        let shape = JunctionShape { n_left: 60, n_right: 20 };
        let var: Vec<f32> = (0..60).map(|i| i as f32).collect();
        let d = variance_out_degrees(&var, 5, 20);
        let p = generate_with_out_degrees(shape, &d, &mut rng);
        p.audit().unwrap();
        assert_eq!(p.n_edges(), 300);
        assert_eq!(p.out_degrees(), d);
        let din = p.in_degrees();
        let (mn, mx) = (din.iter().min().unwrap(), din.iter().max().unwrap());
        assert!(mx - mn <= 2, "in-degrees unbalanced: {din:?}");
    }

    #[test]
    fn net_pattern_density_matches_uniform_target() {
        let mut rng = Rng::new(2);
        let net = NetConfig::new(vec![50, 20, 10]);
        let dout = DoutConfig(vec![4, 5]);
        let var: Vec<f32> = (0..50).map(|_| rng.uniform()).collect();
        let p = generate_net(&net, &dout, &var, &mut rng);
        let uniform = super::super::generate(
            super::super::Method::Structured,
            &net,
            &dout,
            None,
            &mut rng,
        );
        assert_eq!(
            p.junctions[0].n_edges() + p.junctions[1].n_edges(),
            uniform.junctions[0].n_edges() + uniform.junctions[1].n_edges()
        );
    }
}
