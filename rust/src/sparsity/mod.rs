//! Pre-defined sparsity (paper Sec. II): connection-pattern generation,
//! density math, and the clash-free patterns of Sec. III-C / Appendix C.
//!
//! A *pattern* is fixed before training and held fixed through training and
//! inference. Three families are implemented, mirroring Table II:
//! - [`clash_free`]: seed-vector cyclic patterns the hardware can stream
//!   with zero memory contention (most constrained, hardware-friendly),
//! - [`structured`]: fixed out-degree / in-degree, otherwise random,
//! - [`random`]: unconstrained random edges (may disconnect neurons),
//! plus the §V-A [`attention`] baseline with variance-weighted in-layer
//! out-degrees.

pub mod attention;
pub mod clash_free;
pub mod config;
pub mod pattern;
pub mod random;
pub mod structured;

pub use config::{DoutConfig, JunctionShape, NetConfig};
pub use pattern::{NetPattern, Pattern};

use crate::util::rng::Rng;

/// Pattern family selector used by experiments and the CLI.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Seed-vector cyclic patterns (hardware clash-free, Sec. III-C).
    ClashFree,
    /// Fixed in/out degrees, random placement.
    Structured,
    /// Unconstrained random edges.
    Random,
}

impl Method {
    /// Every pattern family, in Table-II order.
    pub const ALL: [Method; 3] = [Method::ClashFree, Method::Structured, Method::Random];

    /// CLI/display name.
    pub fn name(&self) -> &'static str {
        match self {
            Method::ClashFree => "clash-free",
            Method::Structured => "structured",
            Method::Random => "random",
        }
    }

    /// Parse a CLI name (accepts the short aliases `cf`, `s`, `r`).
    pub fn parse(s: &str) -> Option<Method> {
        match s {
            "clash-free" | "clashfree" | "cf" => Some(Method::ClashFree),
            "structured" | "s" => Some(Method::Structured),
            "random" | "r" => Some(Method::Random),
            _ => None,
        }
    }
}

/// Generate a whole-network pattern for `net` with out-degrees `dout`.
///
/// For [`Method::ClashFree`], `znet` (degree-of-parallelism per junction)
/// shapes the pattern; pass `None` to auto-derive a balanced z-config.
pub fn generate(
    method: Method,
    net: &NetConfig,
    dout: &DoutConfig,
    znet: Option<&[usize]>,
    rng: &mut Rng,
) -> NetPattern {
    let junctions: Vec<Pattern> = (0..net.n_junctions())
        .map(|i| {
            let shape = net.junction(i);
            match method {
                Method::Structured => structured::generate(shape, dout.0[i], rng),
                Method::Random => {
                    let edges = shape.n_left * dout.0[i];
                    random::generate(shape, edges, rng)
                }
                Method::ClashFree => {
                    let z = znet
                        .map(|zs| zs[i])
                        .unwrap_or_else(|| clash_free::default_z(shape, dout.0[i]));
                    clash_free::generate(
                        shape,
                        dout.0[i],
                        z,
                        clash_free::Flavor::Type1 { dither: false },
                        rng,
                    )
                }
            }
        })
        .collect();
    NetPattern { junctions }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_roundtrip() {
        for m in Method::ALL {
            assert_eq!(Method::parse(m.name()), Some(m));
        }
        assert_eq!(Method::parse("bogus"), None);
    }

    #[test]
    fn generate_all_methods_produce_valid_patterns() {
        let net = NetConfig::new(vec![32, 16, 8]);
        let dout = DoutConfig(vec![4, 4]);
        let mut rng = Rng::new(0);
        for m in Method::ALL {
            let p = generate(m, &net, &dout, None, &mut rng);
            assert_eq!(p.junctions.len(), 2);
            for (i, j) in p.junctions.iter().enumerate() {
                let shape = net.junction(i);
                assert_eq!(j.shape, shape);
                assert_eq!(j.n_edges(), shape.n_left * dout.0[i]);
                j.audit().expect("valid pattern");
            }
        }
    }
}
