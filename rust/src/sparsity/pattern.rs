//! Connection-pattern representation and audits.
//!
//! Edges are stored per *right* neuron (the paper's edge numbering,
//! Sec. III-B: edges are numbered sequentially top-to-bottom on the right
//! side), which is also the compacted weight-memory layout of Fig. 4.

use super::config::JunctionShape;

/// A single junction's connection pattern.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Pattern {
    /// The junction's layer widths.
    pub shape: JunctionShape,
    /// `in_edges[j]` = left-neuron indices feeding right neuron j,
    /// in edge-number order (so row j is row j of the wc/idx memories).
    pub in_edges: Vec<Vec<u32>>,
}

/// Per-junction patterns for the whole network.
#[derive(Clone, Debug)]
pub struct NetPattern {
    /// One pattern per junction, input side first.
    pub junctions: Vec<Pattern>,
}

impl Pattern {
    /// Total edge count `|W_i|` — the junction's storage and MAC cost.
    pub fn n_edges(&self) -> usize {
        self.in_edges.iter().map(|e| e.len()).sum()
    }

    /// Junction density rho_i = |W_i| / (Nl * Nr).
    pub fn density(&self) -> f64 {
        self.n_edges() as f64 / (self.shape.n_left * self.shape.n_right) as f64
    }

    /// In-degree per right neuron.
    pub fn in_degrees(&self) -> Vec<usize> {
        self.in_edges.iter().map(|e| e.len()).collect()
    }

    /// Out-degree per left neuron.
    pub fn out_degrees(&self) -> Vec<usize> {
        let mut d = vec![0usize; self.shape.n_left];
        for edges in &self.in_edges {
            for &k in edges {
                d[k as usize] += 1;
            }
        }
        d
    }

    /// Structured in the paper's sense: all in-degrees equal and all
    /// out-degrees equal.
    pub fn is_structured(&self) -> bool {
        let din = self.in_degrees();
        let dout = self.out_degrees();
        din.windows(2).all(|w| w[0] == w[1]) && dout.windows(2).all(|w| w[0] == w[1])
    }

    /// Left neurons with no outgoing edge (information irrecoverably lost
    /// — the failure mode of random patterns at low density, Sec. IV-B).
    pub fn disconnected_left(&self) -> usize {
        self.out_degrees().iter().filter(|&&d| d == 0).count()
    }

    /// Right neurons with no incoming edge.
    pub fn disconnected_right(&self) -> usize {
        self.in_degrees().iter().filter(|&&d| d == 0).count()
    }

    /// Structural invariants: indices in range, no duplicate edge into the
    /// same right neuron.
    pub fn audit(&self) -> Result<(), String> {
        if self.in_edges.len() != self.shape.n_right {
            return Err(format!(
                "{} rows for {} right neurons",
                self.in_edges.len(),
                self.shape.n_right
            ));
        }
        for (j, edges) in self.in_edges.iter().enumerate() {
            let mut seen = vec![false; self.shape.n_left];
            for &k in edges {
                if (k as usize) >= self.shape.n_left {
                    return Err(format!("right {j}: left index {k} out of range"));
                }
                if seen[k as usize] {
                    return Err(format!("right {j}: duplicate edge to left {k}"));
                }
                seen[k as usize] = true;
            }
        }
        Ok(())
    }

    /// Dense 0/1 mask, row-major `[n_right, n_left]` — the AOT
    /// artifacts' mask input layout.
    pub fn mask(&self) -> Vec<f32> {
        let mut m = vec![0f32; self.shape.n_right * self.shape.n_left];
        for (j, edges) in self.in_edges.iter().enumerate() {
            for &k in edges {
                m[j * self.shape.n_left + k as usize] = 1.0;
            }
        }
        m
    }

    /// Compacted index memory `[n_right, d_in]` (row-major), the Fig. 4
    /// weight-memory layout. Only defined for uniform in-degree.
    pub fn compact_indices(&self) -> Option<(Vec<i32>, usize)> {
        let din = self.in_edges.first()?.len();
        if din == 0 || !self.in_edges.iter().all(|e| e.len() == din) {
            return None;
        }
        let mut idx = Vec::with_capacity(self.shape.n_right * din);
        for edges in &self.in_edges {
            idx.extend(edges.iter().map(|&k| k as i32));
        }
        Some((idx, din))
    }

    /// Extract the compacted weights `[n_right, d_in]` from a dense
    /// row-major `[n_right, n_left]` weight matrix.
    pub fn compact_weights(&self, dense: &[f32]) -> Vec<f32> {
        assert_eq!(dense.len(), self.shape.n_right * self.shape.n_left);
        let mut wc = Vec::with_capacity(self.n_edges());
        for (j, edges) in self.in_edges.iter().enumerate() {
            for &k in edges {
                wc.push(dense[j * self.shape.n_left + k as usize]);
            }
        }
        wc
    }

    /// Fully-connected pattern.
    pub fn fully_connected(shape: JunctionShape) -> Pattern {
        Pattern {
            shape,
            in_edges: (0..shape.n_right)
                .map(|_| (0..shape.n_left as u32).collect())
                .collect(),
        }
    }
}

impl NetPattern {
    /// Overall density rho_net (eq. 1).
    pub fn rho_net(&self) -> f64 {
        let num: usize = self.junctions.iter().map(|p| p.n_edges()).sum();
        let den: usize = self
            .junctions
            .iter()
            .map(|p| p.shape.n_left * p.shape.n_right)
            .sum();
        num as f64 / den as f64
    }

    /// Total neurons (left of junction 0 + every right layer) with no
    /// connectivity in their adjacent junction.
    pub fn disconnected_neurons(&self) -> usize {
        let mut total = self.junctions[0].disconnected_left();
        for p in &self.junctions {
            total += p.disconnected_right();
        }
        // hidden layers also lose information if their *outgoing* junction
        // drops them
        for p in &self.junctions[1..] {
            total += p.disconnected_left();
        }
        total
    }

    /// All junction masks in [`Pattern::mask`] layout, network order.
    pub fn masks(&self) -> Vec<Vec<f32>> {
        self.junctions.iter().map(|p| p.mask()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Pattern {
        // Fig. 4: N_{i-1}=12, N_i=8, d_in=3, d_out=2
        Pattern {
            shape: JunctionShape { n_left: 12, n_right: 8 },
            in_edges: vec![
                vec![4, 1, 10],
                vec![11, 5, 0],
                vec![2, 7, 6],
                vec![3, 9, 8],
                vec![0, 5, 1],
                vec![4, 10, 11],
                vec![6, 8, 2],
                vec![7, 3, 9],
            ],
        }
    }

    #[test]
    fn toy_pattern_stats() {
        let p = toy();
        assert_eq!(p.n_edges(), 24);
        assert!((p.density() - 0.25).abs() < 1e-12);
        assert!(p.is_structured());
        assert_eq!(p.disconnected_left(), 0);
        assert_eq!(p.disconnected_right(), 0);
        p.audit().unwrap();
    }

    #[test]
    fn mask_layout() {
        let p = toy();
        let m = p.mask();
        assert_eq!(m.len(), 96);
        assert_eq!(m.iter().filter(|&&x| x == 1.0).count(), 24);
        assert_eq!(m[4], 1.0); // right 0 <- left 4
        assert_eq!(m[12 + 11], 1.0); // right 1 <- left 11
        assert_eq!(m[3], 0.0);
    }

    #[test]
    fn compact_roundtrip() {
        let p = toy();
        let (idx, din) = p.compact_indices().unwrap();
        assert_eq!(din, 3);
        assert_eq!(idx.len(), 24);
        assert_eq!(&idx[0..3], &[4, 1, 10]);
        // dense weights where w[j,k] = j*100 + k, compacted row j follows idx
        let mut dense = vec![0f32; 96];
        for j in 0..8 {
            for k in 0..12 {
                dense[j * 12 + k] = (j * 100 + k) as f32;
            }
        }
        let wc = p.compact_weights(&dense);
        assert_eq!(wc[0], 4.0);
        assert_eq!(wc[3], 111.0); // right 1, left 11
    }

    #[test]
    fn audit_rejects_bad_patterns() {
        let mut p = toy();
        p.in_edges[0][1] = 4; // duplicate of first entry
        assert!(p.audit().is_err());
        let mut p2 = toy();
        p2.in_edges[2][0] = 99; // out of range
        assert!(p2.audit().is_err());
    }

    #[test]
    fn fc_pattern() {
        let p = Pattern::fully_connected(JunctionShape { n_left: 5, n_right: 3 });
        assert_eq!(p.n_edges(), 15);
        assert!((p.density() - 1.0).abs() < 1e-12);
        assert!(p.is_structured());
        assert!(p.compact_indices().is_some());
    }

    #[test]
    fn disconnected_counts() {
        let p = Pattern {
            shape: JunctionShape { n_left: 4, n_right: 3 },
            in_edges: vec![vec![0], vec![0], vec![]],
        };
        assert_eq!(p.disconnected_left(), 3);
        assert_eq!(p.disconnected_right(), 1);
        assert!(!p.is_structured());
    }
}
