//! Network/junction configuration and the density math of Sec. II-A and
//! Appendix A.

use crate::util::gcd;

/// Neuronal configuration `N_net = (N_0, ..., N_L)`; layer 0 is the input.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NetConfig {
    /// Layer widths, input first.
    pub layers: Vec<usize>,
}

/// One junction: `n_left = N_{i-1}` nodes on the left, `n_right = N_i`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct JunctionShape {
    /// Left (earlier) layer width.
    pub n_left: usize,
    /// Right (later) layer width.
    pub n_right: usize,
}

/// Out-degree configuration `d_net_out = (d_1_out, ..., d_L_out)`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DoutConfig(pub Vec<usize>);

impl NetConfig {
    /// Validated configuration (>= 2 non-empty layers).
    pub fn new(layers: Vec<usize>) -> Self {
        assert!(layers.len() >= 2, "need at least input + output layer");
        assert!(layers.iter().all(|&n| n > 0), "empty layer");
        Self { layers }
    }

    /// Number of junctions L for an (L+1)-layer MLP.
    pub fn n_junctions(&self) -> usize {
        self.layers.len() - 1
    }

    /// Junction i (0-based; the paper's junction i+1).
    pub fn junction(&self, i: usize) -> JunctionShape {
        JunctionShape {
            n_left: self.layers[i],
            n_right: self.layers[i + 1],
        }
    }

    /// Fully-connected out-degree configuration.
    pub fn fc_dout(&self) -> DoutConfig {
        DoutConfig((1..self.layers.len()).map(|i| self.layers[i]).collect())
    }

    /// `|W_i|` per junction for out-degrees `dout`.
    pub fn edges(&self, dout: &DoutConfig) -> Vec<usize> {
        (0..self.n_junctions())
            .map(|i| self.layers[i] * dout.0[i])
            .collect()
    }

    /// Total trainable parameters (weights + biases) at out-degrees `dout`.
    pub fn trainable_params(&self, dout: &DoutConfig) -> usize {
        self.edges(dout).iter().sum::<usize>() + self.layers[1..].iter().sum::<usize>()
    }

    /// Overall density rho_net (eq. 1).
    pub fn rho_net(&self, dout: &DoutConfig) -> f64 {
        let num: usize = self.edges(dout).iter().sum();
        let den: usize = (0..self.n_junctions())
            .map(|i| self.layers[i] * self.layers[i + 1])
            .sum();
        num as f64 / den as f64
    }

    /// Per-junction densities rho_i = d_out_i / N_i.
    pub fn rho_per_junction(&self, dout: &DoutConfig) -> Vec<f64> {
        (0..self.n_junctions())
            .map(|i| dout.0[i] as f64 / self.layers[i + 1] as f64)
            .collect()
    }

    /// Validate `dout` against the structured constraints (eq. 6):
    /// d_in = N_{i-1} d_out / N_i must be a natural number <= N_{i-1},
    /// and d_out <= N_i.
    pub fn validate_dout(&self, dout: &DoutConfig) -> Result<(), String> {
        if dout.0.len() != self.n_junctions() {
            return Err(format!(
                "dout has {} entries for {} junctions",
                dout.0.len(),
                self.n_junctions()
            ));
        }
        for i in 0..self.n_junctions() {
            let s = self.junction(i);
            let d_out = dout.0[i];
            if d_out == 0 || d_out > s.n_right {
                return Err(format!("junction {i}: d_out {d_out} not in 1..={}", s.n_right));
            }
            if (s.n_left * d_out) % s.n_right != 0 {
                return Err(format!(
                    "junction {i}: d_in = {}*{}/{} is not an integer (Appendix A: d_out must be a multiple of {}/gcd = {})",
                    s.n_left,
                    d_out,
                    s.n_right,
                    s.n_right,
                    s.n_right / gcd(s.n_left, s.n_right)
                ));
            }
        }
        Ok(())
    }

    /// d_in per junction (requires a valid dout).
    pub fn din(&self, dout: &DoutConfig) -> Vec<usize> {
        (0..self.n_junctions())
            .map(|i| {
                let s = self.junction(i);
                s.n_left * dout.0[i] / s.n_right
            })
            .collect()
    }
}

impl JunctionShape {
    /// The set of admissible densities (eq. 7): { k / gcd(Nl, Nr) }.
    pub fn density_set(&self) -> Vec<f64> {
        let g = gcd(self.n_left, self.n_right);
        (1..=g).map(|k| k as f64 / g as f64).collect()
    }

    /// Number of admissible (d_out, d_in) pairs = gcd(Nl, Nr) (Appendix A).
    pub fn n_density_choices(&self) -> usize {
        gcd(self.n_left, self.n_right)
    }

    /// Smallest admissible d_out (= N_i / gcd).
    pub fn min_dout(&self) -> usize {
        self.n_right / gcd(self.n_left, self.n_right)
    }

    /// The admissible out-degree closest to a target density rho.
    pub fn dout_for_density(&self, rho: f64) -> usize {
        let step = self.min_dout();
        let k = (rho * self.n_right as f64 / step as f64).round().max(1.0) as usize;
        (k * step).min(self.n_right)
    }
}

impl DoutConfig {
    /// Paper notation, e.g. "(20, 10)".
    pub fn show(&self) -> String {
        let inner: Vec<String> = self.0.iter().map(|d| d.to_string()).collect();
        format!("({})", inner.join(", "))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mnist() -> NetConfig {
        NetConfig::new(vec![800, 100, 10])
    }

    #[test]
    fn rho_net_matches_paper_table1() {
        // N_net = (800,100,10), d_out = (20,10): rho_net = 21% (Table I).
        let net = mnist();
        let dout = DoutConfig(vec![20, 10]);
        let rho = net.rho_net(&dout);
        assert!((rho - 0.2098).abs() < 1e-3, "rho={rho}");
        assert_eq!(net.edges(&dout), vec![16_000, 1_000]);
    }

    #[test]
    fn fc_dout_gives_density_one() {
        let net = mnist();
        let fc = net.fc_dout();
        assert_eq!(fc.0, vec![100, 10]);
        assert!((net.rho_net(&fc) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn din_math() {
        // Table I: W storage = sum N_i d_in_i = 17000 for sparse.
        let net = mnist();
        let dout = DoutConfig(vec![20, 10]);
        let din = net.din(&dout);
        assert_eq!(din, vec![160, 100]);
        let w: usize = din.iter().zip(&net.layers[1..]).map(|(d, n)| d * n).sum();
        assert_eq!(w, 17_000);
    }

    #[test]
    fn appendix_a_density_sets() {
        // N_net = (117, 390, 13): gcd(117,390)=39 choices, gcd(390,13)=13.
        let net = NetConfig::new(vec![117, 390, 13]);
        assert_eq!(net.junction(0).n_density_choices(), 39);
        assert_eq!(net.junction(1).n_density_choices(), 13);
        let set = net.junction(1).density_set();
        assert_eq!(set.len(), 13);
        assert!((set[0] - 1.0 / 13.0).abs() < 1e-12);
        assert!((set[12] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn validate_dout_rejects_fractional_din() {
        let net = NetConfig::new(vec![117, 390, 13]);
        // junction 0: min d_out = 390/39 = 10; d_out=5 is invalid
        assert!(net.validate_dout(&DoutConfig(vec![5, 1])).is_err());
        assert!(net.validate_dout(&DoutConfig(vec![10, 1])).is_ok());
        assert_eq!(net.junction(0).min_dout(), 10);
    }

    #[test]
    fn validate_dout_bounds() {
        let net = mnist();
        assert!(net.validate_dout(&DoutConfig(vec![101, 10])).is_err()); // > N_1
        assert!(net.validate_dout(&DoutConfig(vec![0, 10])).is_err());
        assert!(net.validate_dout(&DoutConfig(vec![20])).is_err()); // wrong len
    }

    #[test]
    fn dout_for_density_snaps_to_admissible() {
        let j = JunctionShape { n_left: 117, n_right: 390 };
        let d = j.dout_for_density(0.5);
        assert_eq!(d % j.min_dout(), 0);
        assert!((d as f64 / 390.0 - 0.5).abs() < 0.05);
    }

    #[test]
    fn trainable_params() {
        let net = mnist();
        assert_eq!(net.trainable_params(&net.fc_dout()), 80_000 + 1_000 + 110);
    }
}
