//! # predef-sparse
//!
//! Reproduction of Dey et al., "Pre-Defined Sparse Neural Networks with
//! Hardware Acceleration" (IEEE JETCAS 2019): pre-defined sparse MLPs with
//! clash-free hardware-friendly connection patterns, a cycle-accurate
//! simulator of the paper's edge-based FPGA architecture, and a Rust
//! coordinator executing AOT-compiled JAX/Pallas artifacts via PJRT.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured results.
pub mod sparsity;
pub mod hw;
pub mod data;
pub mod nn;
pub mod runtime;
pub mod coordinator;
pub mod exp;
pub mod util;
