//! # predef-sparse
//!
//! Reproduction of Dey et al., "Pre-Defined Sparse Neural Networks with
//! Hardware Acceleration" (IEEE JETCAS 2019 / arXiv:1812.01164):
//! pre-defined sparse MLPs with clash-free hardware-friendly connection
//! patterns, a cycle-accurate simulator of the paper's edge-based FPGA
//! architecture, and a Rust coordinator executing training and
//! multi-worker batched inference over a pluggable runtime — the
//! pure-Rust parallel [`runtime::NativeEngine`] by default, or
//! AOT-compiled JAX artifacts via PJRT behind the `pjrt` cargo feature.
//!
//! ## Module tree vs. the paper
//!
//! | module | paper | role |
//! |---|---|---|
//! | [`sparsity`] | Sec. II, III-C, App. A/C | density math, clash-free / structured / random pattern generators, audits |
//! | [`hw`] | Sec. III, Table I | cycle-accurate junction/pipeline simulator, banked memories, storage model |
//! | [`nn`] | Sec. II eq. 2–4, Sec. III-A/D | reference dense + CSR compacted kernels (batch-parallel), Adam trainers, the pipelined training engine ([`nn::pipeline`]) executing the FF/BP/UP interleave, and the Qm.n fixed-point execution path ([`nn::fixed`]) |
//! | [`runtime`] | — | backend-agnostic [`runtime::Engine`] facade: native or PJRT execution of the manifest programs, plus the native-only streaming `train_pipelined` path |
//! | [`analysis`] | Sec. III-B/C, arXiv:1806.01087 | static verifier (`pds analyze`): clash-freedom prover over the pipelined interleave, Qm.n interval range analysis, manifest lint — typed findings, no execution |
//! | [`coordinator`] | Sec. III (scale-out analogue) | training sessions (fused + pipelined); the multi-worker sharded inference service + load generator |
//! | [`net`] | Sec. III (network-edge analogue) | binary wire protocol, event-loop TCP front-end ([`net::NetServer`]: one reactor thread, thousands of connections), adaptive micro-batching into engine batches, blocking pipelined [`net::NetClient`] |
//! | [`data`] | Sec. IV | synthetic class-conditional surrogates for MNIST / Reuters / TIMIT / CIFAR |
//! | [`exp`] | Sec. IV figures/tables | the paper's experiment harnesses (`pds exp <id>`) |
//! | [`obs`] | Sec. IV (measurement), arXiv:1806.01087 | unified observability: metrics registry + snapshot exposition, sampled request tracing (Chrome `trace_event` export), per-junction FF/BP/UP stage profiling |
//! | [`util`] | — | in-tree rng / json / bench / property-test / fork-join replacements |
//!
//! See `ARCHITECTURE.md` (next to this crate) for the paper-figure →
//! module map and the pipeline timing diagram, `DESIGN.md` for the
//! system inventory and the performance notes, and the top-level
//! `README.md` for a quickstart.

// every public item is documented; CI builds rustdoc with -D warnings,
// so this keeps the crate-wide documentation contract enforced
#![warn(missing_docs)]
// numerics code: index-based loops over multiple parallel buffers are the
// clearest expression of the paper's equations
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]
#![allow(clippy::many_single_char_names)]

pub mod analysis;
pub mod sparsity;
pub mod hw;
pub mod data;
pub mod nn;
pub mod runtime;
pub mod coordinator;
pub mod net;
pub mod exp;
pub mod obs;
pub mod util;
