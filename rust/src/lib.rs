//! # predef-sparse
//!
//! Reproduction of Dey et al., "Pre-Defined Sparse Neural Networks with
//! Hardware Acceleration" (IEEE JETCAS 2019): pre-defined sparse MLPs with
//! clash-free hardware-friendly connection patterns, a cycle-accurate
//! simulator of the paper's edge-based FPGA architecture, and a Rust
//! coordinator executing training and batched inference over a pluggable
//! runtime — the pure-Rust parallel [`runtime::NativeEngine`] by default,
//! or AOT-compiled JAX/Pallas artifacts via PJRT behind the `pjrt` cargo
//! feature.
//!
//! See DESIGN.md (in this directory) for the system inventory, the
//! backend architecture, and the performance notes.

// numerics code: index-based loops over multiple parallel buffers are the
// clearest expression of the paper's equations
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]
#![allow(clippy::many_single_char_names)]

pub mod sparsity;
pub mod hw;
pub mod data;
pub mod nn;
pub mod runtime;
pub mod coordinator;
pub mod exp;
pub mod util;
