//! Hardware-architecture walkthrough: builds the paper's Fig. 4 junction
//! and a production-sized one on the cycle-accurate simulator, runs
//! FF/BP/UP, verifies clash-freedom and cycle counts, and prints the
//! pipeline timetable of Fig. 2(c).
//!
//!     cargo run --release --example hw_sim

use pds::hw::junction::{Act, JunctionUnit};
use pds::hw::pipeline::Pipeline;
use pds::sparsity::clash_free::{pattern_from_schedule, schedule, Flavor};
use pds::sparsity::config::JunctionShape;
use pds::util::rng::Rng;

fn run_junction(nl: usize, nr: usize, d_out: usize, z: usize, seed: u64) {
    let shape = JunctionShape { n_left: nl, n_right: nr };
    let d_in = nl * d_out / nr;
    let mut rng = Rng::new(seed);
    let sched = schedule(nl, z, d_out, Flavor::Type1 { dither: false }, &mut rng);
    sched.verify_clash_free().unwrap();
    let p = pattern_from_schedule(shape, d_in, &sched).unwrap();
    let z_next = JunctionUnit::required_z_next(nr * d_in, z, d_in);
    let mut unit = JunctionUnit::new(shape, d_in, sched, z_next);
    let dense: Vec<f32> = (0..nr * nl).map(|_| rng.normal()).collect();
    unit.load_weights_dense(&dense);

    println!(
        "\njunction {nl}x{nr}  d_out={d_out} d_in={d_in}  z={z} (D={} deep, {} sweeps)  C={} cycles",
        nl / z,
        d_out,
        unit.junction_cycle
    );
    println!(
        "  pattern: {} edges, density {:.1}%, structured={}",
        p.n_edges(),
        p.density() * 100.0,
        p.is_structured()
    );
    let a: Vec<f32> = (0..nl).map(|_| rng.normal()).collect();
    let bias = vec![0.1f32; nr];
    let ff = unit.feedforward(&a, &bias, Act::Relu).unwrap();
    println!(
        "  FF: {} cycles, {} weight reads, ≤{} right neurons/cycle (z_next {})",
        ff.stats.cycles, ff.stats.weight_reads, ff.stats.max_rights_per_cycle, z_next
    );
    let dr: Vec<f32> = (0..nr).map(|_| rng.normal()).collect();
    // BP consumes the *left* layer's activation derivatives (from the
    // previous junction's FF); use ones for this standalone walkthrough.
    let adot_left = vec![1.0f32; nl];
    let (_, bp) = unit.backprop(&dr, &adot_left).unwrap();
    let _ = &ff.adot;
    let mut b2 = bias;
    let up = unit.update(&a, &dr, &mut b2, 0.01).unwrap();
    println!("  BP: {} cycles | UP: {} cycles — all clash-free", bp.cycles, up.cycles);
}

fn main() {
    // the paper's worked toy example (Fig. 4)
    run_junction(12, 8, 2, 4, 1);
    // its FC variant at the same z (Sec. III-E: 4X longer junction cycle)
    run_junction(12, 8, 8, 4, 2);
    // a production-sized junction (Table I / Table II MNIST row)
    run_junction(800, 100, 20, 200, 3);

    // Fig. 2(c) pipeline timetable for L = 2
    println!("\nFig. 2(c) timetable, L = 2 (junction, op, input#):");
    let p = Pipeline::new(2);
    p.audit(50).unwrap();
    for tau in 0..8 {
        let slots: Vec<String> = p
            .slots_at(tau)
            .iter()
            .map(|(i, op, n)| format!("J{i}:{}({n})", op.name()))
            .collect();
        println!("  junction-cycle {tau}: {}", slots.join("  "));
    }
    println!(
        "steady state: {} ops per junction cycle (3L - 1), ~{}X speedup over sequential",
        p.steady_state_ops(),
        p.steady_state_ops()
    );
}
