//! Inference-service example: dynamic batching over the fixed-batch
//! forward program, with latency/throughput reporting — the software
//! analogue of feeding the junction pipeline one input per junction
//! cycle. Runs on the parallel native backend by default (PJRT with
//! `--features pjrt` after `make artifacts`).
//!
//!     cargo run --release --example serve

use std::time::{Duration, Instant};

use pds::coordinator::{InferenceServer, ServerConfig};
use pds::runtime::Manifest;
use pds::sparsity::config::{DoutConfig, NetConfig};
use pds::sparsity::{generate, Method};
use pds::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let config = "mnist_fc2";
    let probe = Manifest::probe(dir, config)?;
    let netc = NetConfig::new(probe.layers.clone());
    let mut rng = Rng::new(5);
    let pattern = generate(
        Method::ClashFree,
        &netc,
        &DoutConfig(vec![20, 10]),
        None,
        &mut rng,
    );

    for wait_ms in [1u64, 5, 20] {
        let server = InferenceServer::start(
            dir,
            config,
            &pattern,
            None,
            ServerConfig {
                max_wait: Duration::from_millis(wait_ms),
            },
        )?;
        let n_clients = 8usize;
        let per_client = 100usize;
        let t0 = Instant::now();
        let mut handles = Vec::new();
        for c in 0..n_clients {
            let client = server.client();
            let features = probe.layers[0];
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(900 + c as u64);
                let mut lats = Vec::with_capacity(per_client);
                for _ in 0..per_client {
                    let x: Vec<f32> = (0..features).map(|_| rng.normal()).collect();
                    lats.push(client.classify(x).unwrap().latency);
                }
                lats
            }));
        }
        let mut lats: Vec<Duration> = Vec::new();
        for h in handles {
            lats.extend(h.join().unwrap());
        }
        let wall = t0.elapsed();
        lats.sort();
        let batches = server.stats.batches.load(std::sync::atomic::Ordering::Relaxed);
        println!(
            "max_wait {wait_ms:>2}ms: {:>6.0} req/s | p50 {:>9.2?} p95 {:>9.2?} p99 {:>9.2?} | {} batches (occupancy {:.1}/{})",
            lats.len() as f64 / wall.as_secs_f64(),
            lats[lats.len() / 2],
            lats[lats.len() * 95 / 100],
            lats[lats.len() * 99 / 100],
            batches,
            lats.len() as f64 / batches.max(1) as f64,
            probe.batch
        );
        server.shutdown()?;
    }
    println!("\n(larger max_wait -> fuller batches -> higher throughput, higher latency)");
    Ok(())
}
