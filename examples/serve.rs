//! Multi-worker inference-service walkthrough — and smoke test.
//!
//! Serves two manifest configs from one service, drives closed-loop
//! load, and shows the dynamic batcher's latency/throughput knob
//! (`max_wait`). Every step asserts on its outputs, so a green run is a
//! real end-to-end check of the serving layer (referenced from the
//! top-level README §Examples).
//!
//!     cargo run --release --example serve

use std::sync::atomic::Ordering;
use std::time::Duration;

use pds::coordinator::loadgen::{self, LoadSpec};
use pds::coordinator::{InferenceService, ServerConfig};

fn main() -> anyhow::Result<()> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");

    // Step 1: pick two models. A "model" for the service is a manifest
    // config plus a pre-defined sparse connection pattern; model_spec
    // builds a clash-free ~25%-density pattern for each config (the
    // same construction `pds serve` uses). Both run on the parallel
    // native backend by default (PJRT with `--features pjrt` after
    // `make artifacts`).
    let models = vec!["tiny".to_string(), "mnist_fc2".to_string()];

    // Step 2: sweep the dynamic batcher's flush timeout. The compiled
    // executable always pays one fixed-batch execution per flush, so a
    // larger max_wait collects fuller batches: higher throughput, but
    // up to max_wait of extra queueing latency per request.
    for wait_ms in [1u64, 5] {
        let specs = models
            .iter()
            .map(|m| loadgen::model_spec(dir, m, 0.25, 5))
            .collect::<anyhow::Result<Vec<_>>>()?;

        // Step 3: start the service — 2 workers per model, each owning
        // its own engine (backend handles are thread-affine) and one
        // bounded request shard. The router fills the shallowest shard;
        // dry workers steal from the deepest sibling.
        let svc = InferenceService::start(
            dir,
            specs,
            ServerConfig {
                max_wait: Duration::from_millis(wait_ms),
                workers: 2,
                queue_depth: 256,
                tune_kernel_threads: true,
            },
        )?;

        // Step 4: drive both models concurrently with closed-loop
        // clients (each waits for its reply before submitting again, so
        // in-flight load is bounded by the client count).
        let load = LoadSpec {
            clients: 6,
            requests: 50,
            think_time: Duration::ZERO,
            burst: 1,
            contexts: 1,
        };
        let reports = loadgen::run_load(&svc, &models, &load, 11)?;

        println!("max_wait {wait_ms}ms:");
        for r in &reports {
            r.print();
            // smoke-test assertions: nothing lost, quantiles ordered
            assert_eq!(
                r.served,
                (load.clients * load.requests) as u64,
                "{}: every request must be answered",
                r.model
            );
            assert!(r.p50 <= r.p99, "{}: latency quantiles must be ordered", r.model);
            assert!(r.throughput > 0.0);
        }

        // Step 5: the metrics registry must agree with itself — the
        // occupancy histogram, weighted by occupancy, is exactly the
        // number of served requests.
        for m in &models {
            let met = svc.metrics(m).unwrap();
            let hist = met.occupancy_histogram();
            let weighted: u64 = hist
                .iter()
                .enumerate()
                .map(|(k, &c)| (k as u64 + 1) * c)
                .sum();
            assert_eq!(weighted, met.requests.load(Ordering::Relaxed), "{m}");
            println!("{}", met.report(m));
        }
        svc.shutdown()?;
    }

    println!("\n(larger max_wait -> fuller batches -> higher throughput, higher tail latency)");
    println!("serve example OK");
    Ok(())
}
