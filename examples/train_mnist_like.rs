//! End-to-end headline run: train the paper's Table-I network —
//! N_net = (800, 100, 10) — both fully-connected and at rho_net = 21%
//! clash-free pre-defined sparsity, through the coordinator ->
//! runtime-engine stack (parallel native backend by default; with
//! `--features pjrt` after `make artifacts`, the AOT-compiled JAX train
//! step whose junctions are Pallas FF/BP/UP kernels, on PJRT CPU).
//!
//! Logs the loss curve and reports the paper's core claim: ~4.8X fewer
//! MACs / ~3.9X less weight storage at near-FC accuracy.
//!
//!     cargo run --release --example train_mnist_like

use pds::coordinator::TrainSession;
use pds::data::Spec;
use pds::hw::storage::StorageComparison;
use pds::runtime::Engine;
use pds::sparsity::config::{DoutConfig, NetConfig};
use pds::sparsity::pattern::{NetPattern, Pattern};
use pds::sparsity::{generate, Method};
use pds::util::rng::Rng;

fn train(
    engine: &Engine,
    pattern: NetPattern,
    label: &str,
    splits: &pds::data::Splits,
    epochs: usize,
) -> anyhow::Result<f64> {
    let rho = pattern.rho_net();
    let mut session = TrainSession::new(engine, "mnist_fc2", &pattern, 1e-3, 1e-4, 7)?;
    let mut rng = Rng::new(11);
    println!("\n=== {label}: rho_net = {:.1}%, params = {} weights ===", rho * 100.0,
        pattern.junctions.iter().map(|j| j.n_edges()).sum::<usize>());
    let t0 = std::time::Instant::now();
    let mut final_test = 0.0;
    for e in 0..epochs {
        let (loss, train_acc) = session.epoch(&splits.train, &mut rng)?;
        final_test = session.evaluate(&splits.test)?;
        println!(
            "epoch {e:>2}: loss {loss:.4}  train acc {:.1}%  test acc {:.1}%  ({:.1?} elapsed)",
            train_acc * 100.0,
            final_test * 100.0,
            t0.elapsed()
        );
    }
    session.check_mask_invariant()?;
    println!("{label}: mask invariant verified (excluded edges exactly zero)");
    Ok(final_test)
}

fn main() -> anyhow::Result<()> {
    let engine = Engine::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))?;
    println!("runtime platform: {}", engine.platform());
    let netc = NetConfig::new(vec![800, 100, 10]);
    let dout = DoutConfig(vec![20, 10]);

    // mnist-like surrogate sized to the artifact's batch (256)
    let spec = Spec::mnist_like();
    let batch = engine.manifest.configs["mnist_fc2"].batch;
    let splits = spec.splits(batch * 16, 0, batch * 4, 42);
    println!(
        "dataset: {} ({} train / {} test, {} features, {} classes)",
        spec.name, splits.train.n, splits.test.n, spec.features, spec.classes
    );

    let epochs: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(8);

    // FC reference
    let fc_pattern = NetPattern {
        junctions: (0..netc.n_junctions())
            .map(|i| Pattern::fully_connected(netc.junction(i)))
            .collect(),
    };
    let fc_acc = train(&engine, fc_pattern, "FC", &splits, epochs)?;

    // 21% clash-free sparse (the Table-I operating point)
    let mut rng = Rng::new(3);
    let sparse_pattern = generate(Method::ClashFree, &netc, &dout, Some(&[160, 10]), &mut rng);
    let sparse_acc = train(&engine, sparse_pattern, "sparse 21% (clash-free)", &splits, epochs)?;

    let cmp = StorageComparison::new(&netc, &dout);
    println!("\n================ headline ================");
    println!(
        "FC test acc: {:.1}% | sparse (rho=21%) test acc: {:.1}% | gap {:+.1} pts",
        fc_acc * 100.0,
        sparse_acc * 100.0,
        (sparse_acc - fc_acc) * 100.0
    );
    println!(
        "at {:.1}X less weight storage and {:.1}X fewer training MACs (paper: 98.0% -> 97.2% at 3.9X / 4.8X)",
        cmp.memory_reduction(),
        cmp.compute_reduction()
    );
    Ok(())
}
