//! Quickstart walkthrough — and smoke test.
//!
//! Generates a hardware-friendly clash-free sparse pattern for the
//! paper's Table-I network, inspects its storage/compute savings, and
//! runs batched inference through the runtime engine (the parallel
//! native backend by default; the AOT PJRT artifacts with
//! `--features pjrt` after `make artifacts`). Each step asserts on its
//! outputs, so a green run doubles as an end-to-end check (referenced
//! from the top-level README §Examples).
//!
//!     cargo run --release --example quickstart

use pds::hw::storage::StorageComparison;
use pds::runtime::{Engine, Value};
use pds::sparsity::config::{DoutConfig, NetConfig};
use pds::sparsity::{generate, Method};
use pds::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // Step 1: the paper's Table-I configuration: N_net = (800, 100, 10)
    // with out-degrees d_out = (20, 10), i.e. rho_net ~ 21% — each
    // input neuron keeps 20 of its 100 possible outgoing edges.
    let netc = NetConfig::new(vec![800, 100, 10]);
    let dout = DoutConfig(vec![20, 10]);
    netc.validate_dout(&dout).map_err(|e| anyhow::anyhow!(e))?;

    // Step 2: a clash-free pre-defined sparse pattern (Sec. III-C).
    // Clash-freedom means the pattern streams through the paper's
    // banked memories with zero contention; z = (160, 10) sets the
    // per-junction degree of hardware parallelism.
    let mut rng = Rng::new(7);
    let pattern = generate(Method::ClashFree, &netc, &dout, Some(&[160, 10]), &mut rng);
    println!(
        "pattern: rho_net = {:.1}%, edges per junction = {:?}",
        pattern.rho_net() * 100.0,
        pattern.junctions.iter().map(|j| j.n_edges()).collect::<Vec<_>>()
    );
    // 800*20 + 100*10 = 17000 edges of 81000 possible = 20.99%
    assert!((pattern.rho_net() - 0.2099).abs() < 0.005, "Table-I density");
    for (i, j) in pattern.junctions.iter().enumerate() {
        j.audit().map_err(|e| anyhow::anyhow!(e))?;
        println!(
            "  junction {}: structured={}, disconnected neurons = {}",
            i + 1,
            j.is_structured(),
            j.disconnected_left() + j.disconnected_right()
        );
        // structured patterns never strand a neuron — the failure mode
        // of random patterns at low density (Sec. IV-B)
        assert!(j.is_structured(), "clash-free patterns are structured");
        assert_eq!(j.disconnected_left() + j.disconnected_right(), 0);
    }

    // Step 3: what the hardware saves (Table I): words of weight
    // storage and MACs drop with the edge count.
    let cmp = StorageComparison::new(&netc, &dout);
    println!(
        "storage: FC {} words -> sparse {} words ({:.1}X); compute {:.1}X fewer MACs",
        cmp.fc.total(),
        cmp.sparse.total(),
        cmp.memory_reduction(),
        cmp.compute_reduction()
    );
    assert!(cmp.memory_reduction() > 2.0, "sparsity must shrink storage");
    assert!(cmp.compute_reduction() > 2.0, "sparsity must shrink compute");

    // Step 4: batched inference through the runtime engine. The
    // mnist_fc2 config has exactly this shape; the masked-dense forward
    // program takes [w_i, b_i] per junction, the pattern's masks, and
    // one fixed-size input batch.
    let engine = Engine::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))?;
    let prog = engine.load("mnist_fc2", "forward")?;
    let batch = engine.manifest.configs["mnist_fc2"].batch;
    let mut inputs: Vec<Value> = Vec::new();
    for (i, p) in pattern.junctions.iter().enumerate() {
        let (nl, nr) = (netc.layers[i], netc.layers[i + 1]);
        let std = (2.0 / nl as f32).sqrt();
        let mask = p.mask();
        let w: Vec<f32> = mask.iter().map(|&m| rng.normal() * std * m).collect();
        inputs.push(Value::F32(w, vec![nr, nl]));
        inputs.push(Value::F32(vec![0.1; nr], vec![nr]));
    }
    for p in &pattern.junctions {
        inputs.push(Value::F32(p.mask(), vec![p.shape.n_right, p.shape.n_left]));
    }
    let x: Vec<f32> = (0..batch * 800).map(|_| rng.normal()).collect();
    inputs.push(Value::F32(x, vec![batch, 800]));
    let t0 = std::time::Instant::now();
    let out = prog.run(&inputs)?;
    let logits = out[0].as_f32()?;
    println!(
        "forward ({}): batch {} in {:?}, logits[0][..4] = {:?}",
        engine.platform(),
        batch,
        t0.elapsed(),
        &logits[..4]
    );
    assert_eq!(logits.len(), batch * 10, "one 10-class logit row per input");
    assert!(logits.iter().all(|v| v.is_finite()), "logits must be finite");

    println!("quickstart OK");
    Ok(())
}
