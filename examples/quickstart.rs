//! Quickstart: generate a hardware-friendly clash-free sparse pattern for
//! the paper's Table-I network, inspect its storage/compute savings, and
//! run inference through the runtime engine (the parallel native backend
//! by default; the AOT PJRT artifacts with `--features pjrt` after
//! `make artifacts`).
//!
//!     cargo run --release --example quickstart

use pds::hw::storage::StorageComparison;
use pds::runtime::{Engine, Value};
use pds::sparsity::config::{DoutConfig, NetConfig};
use pds::sparsity::{generate, Method};
use pds::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // 1. The paper's Table-I configuration: N_net = (800, 100, 10) at
    //    d_out = (20, 10), i.e. rho_net = 21%.
    let netc = NetConfig::new(vec![800, 100, 10]);
    let dout = DoutConfig(vec![20, 10]);
    netc.validate_dout(&dout).map_err(|e| anyhow::anyhow!(e))?;

    // 2. A clash-free pre-defined sparse pattern (streams on the paper's
    //    architecture with zero memory contention).
    let mut rng = Rng::new(7);
    let pattern = generate(Method::ClashFree, &netc, &dout, Some(&[160, 10]), &mut rng);
    println!(
        "pattern: rho_net = {:.1}%, edges per junction = {:?}",
        pattern.rho_net() * 100.0,
        pattern.junctions.iter().map(|j| j.n_edges()).collect::<Vec<_>>()
    );
    for (i, j) in pattern.junctions.iter().enumerate() {
        j.audit().map_err(|e| anyhow::anyhow!(e))?;
        println!(
            "  junction {}: structured={}, disconnected neurons = {}",
            i + 1,
            j.is_structured(),
            j.disconnected_left() + j.disconnected_right()
        );
    }

    // 3. What the hardware saves (Table I).
    let cmp = StorageComparison::new(&netc, &dout);
    println!(
        "storage: FC {} words -> sparse {} words ({:.1}X); compute {:.1}X fewer MACs",
        cmp.fc.total(),
        cmp.sparse.total(),
        cmp.memory_reduction(),
        cmp.compute_reduction()
    );

    // 4. Inference through the runtime engine (mnist_fc2 config has
    //    exactly this shape). Masked-dense path with the pattern's mask.
    let engine = Engine::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))?;
    let prog = engine.load("mnist_fc2", "forward")?;
    let batch = engine.manifest.configs["mnist_fc2"].batch;
    let mut inputs: Vec<Value> = Vec::new();
    for (i, p) in pattern.junctions.iter().enumerate() {
        let (nl, nr) = (netc.layers[i], netc.layers[i + 1]);
        let std = (2.0 / nl as f32).sqrt();
        let mask = p.mask();
        let w: Vec<f32> = mask.iter().map(|&m| rng.normal() * std * m).collect();
        inputs.push(Value::F32(w, vec![nr, nl]));
        inputs.push(Value::F32(vec![0.1; nr], vec![nr]));
    }
    for p in &pattern.junctions {
        inputs.push(Value::F32(p.mask(), vec![p.shape.n_right, p.shape.n_left]));
    }
    let x: Vec<f32> = (0..batch * 800).map(|_| rng.normal()).collect();
    inputs.push(Value::F32(x, vec![batch, 800]));
    let t0 = std::time::Instant::now();
    let out = prog.run(&inputs)?;
    println!(
        "forward ({}): batch {} in {:?}, logits[0][..4] = {:?}",
        engine.platform(),
        batch,
        t0.elapsed(),
        &out[0].as_f32()?[..4]
    );
    Ok(())
}
